"""Per-instance SLO value curves (piecewise-affine VoS) — PR 5.

Four pillars:

  * **ValueCurve unit coverage** — constructors (step / linear decay /
    segmented exponential / constant), evaluation in every region (flat,
    mid-decay, past-hard), energy weighting, validation, and the
    float-monotonicity contract the scheduling engine relies on
    (non-increasing *as computed*, probed with nextafter around every
    breakpoint).
  * **Heterogeneous-curve differentials** — schedules under per-instance
    curve mixes must be byte-identical to the frozen reference engine
    (golden pin + hypothesis differential), and the online driver must
    match the batch path even when floor order differs from arrival order
    (a late high-value instance jumping the admission gate).
  * **Elastic path** — curves survive ``OnlineDriver.repool``, pinned
    against ``restart_from_history`` with the same curve map.
  * **API edges** — legacy ``value_fn`` stays the documented slow path and
    is exclusive with structured curves; ``submit(curve=...)`` requires
    the VoS policy; ``system_vos(strict=True)`` fails loud on missing
    specs.
"""

import json
import math
import os

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import schedulers as S
from repro.core.cost_model import CostModel
from repro.core.dag import merge
from repro.core.online import OnlineDriver, restart_from_history
from repro.core.resources import paper_pool
from repro.core.schedulers import assignment_digest, schedule
from repro.core.schedulers_reference import schedule_reference
from repro.core.simulator import run_instances
from repro.core.vos import (
    ValueCurve,
    VoSSpec,
    exponential_decay,
    instance_curves,
    instance_id,
    linear_decay,
    slo_mix,
    system_vos,
)
from repro.pipeline.workloads import ds_workload

GOLDEN = os.path.join(os.path.dirname(__file__), "golden_sched.json")


def _tuples(sched):
    return [
        (a.task, a.op, a.pe, a.start, a.finish, a.comm_wait, a.energy)
        for a in sched.assignments
    ]


# ---------------------------------------------------------------------------
# ValueCurve construction and evaluation
# ---------------------------------------------------------------------------


def test_step_curve():
    c = ValueCurve.step(10.0, value=3.0)
    assert c.value(0.0) == 3.0
    assert c.value(9.999) == 3.0
    assert c.value(10.0) == 0.0  # left-closed segments: the drop is at 10
    assert c.value(1e9) == 0.0


def test_linear_decay_curve_regions():
    c = ValueCurve.linear_decay(20.0, 60.0, value=2.0)
    # flat region returns the anchor value exactly (no arithmetic)
    assert c.value(0.0) == 2.0
    assert c.value(20.0) == 2.0
    # mid-decay agrees with the legacy closed form to float tolerance
    mid = c.value(40.0)
    assert mid == pytest.approx(linear_decay(40.0, 20.0, 60.0, 2.0), rel=1e-12)
    assert 0.0 < mid < 2.0
    # past the hard deadline the value is exactly zero
    assert c.value(60.0) == 0.0
    assert c.value(61.0) == 0.0


def test_exponential_curve_approximates_exp():
    tau, value = 30.0, 2.0
    c = ValueCurve.exponential(tau, value=value, segments=16)
    # exact at the chord anchors
    for j in range(17):
        t = 4.0 * tau * j / 16
        assert c.value(t) == pytest.approx(value * math.exp(-t / tau), rel=1e-12)
    # chords of a convex function sit above it, within the sagitta bound
    for t in [1.0, 17.3, 55.5, 99.9]:
        exact = value * math.exp(-t / tau)
        assert c.value(t) >= exact - 1e-12
        assert c.value(t) <= exact + 0.02 * value
    # flat beyond the horizon
    assert c.value(4.0 * tau) == c.value(1e9)


def test_constant_and_from_spec():
    assert ValueCurve.constant(5.0).value(1e12) == 5.0
    spec = VoSSpec(10.0, 40.0, value=1.5, energy_weight=0.25)
    c = ValueCurve.from_spec(spec)
    for f in (0.0, 10.0, 25.0, 39.0, 40.0, 50.0):
        assert c.of(f, energy=2.0) == pytest.approx(spec.of(f, energy=2.0), rel=1e-12)


def test_energy_weight_rides_on_curve():
    c = ValueCurve.step(10.0, value=1.0, energy_weight=0.5)
    assert c.of(5.0, energy=1.0) == 0.5
    # None defers the discount to the caller
    assert ValueCurve.step(10.0).of(5.0, energy=1.0) == 1.0


def test_shifted():
    c = ValueCurve.linear_decay(10.0, 30.0)
    s = c.shifted(100.0)
    for f in (0.0, 5.0, 10.0, 20.0, 29.9, 30.0, 80.0):
        assert s.value(f + 100.0) == pytest.approx(c.value(f), rel=1e-12)
    assert s.value(50.0) == 1.0  # still inside the shifted flat region
    with pytest.raises(ValueError, match="forward"):
        c.shifted(-1.0)


def test_curve_validation_rejects_bad_shapes():
    with pytest.raises(ValueError, match="slopes"):
        ValueCurve((10.0,), (1.0, 0.5), (0.1, 0.0))  # growing segment
    with pytest.raises(ValueError, match="non-increasing"):
        ValueCurve((10.0,), (1.0, 2.0), (0.0, 0.0))  # value jumps up
    with pytest.raises(ValueError, match="strictly"):
        ValueCurve((10.0, 10.0), (1.0, 1.0, 0.0), (0.0, 0.0, 0.0))
    with pytest.raises(ValueError, match="len"):
        ValueCurve((10.0,), (1.0,), (0.0,))
    with pytest.raises(ValueError, match="soft"):
        ValueCurve.linear_decay(30.0, 10.0)


def test_curve_float_monotonicity_contract():
    """value() must be non-increasing *as computed in floats* — the
    engine's monotone-key invariant and the admission gate's floor bound
    both depend on it, including right at segment boundaries where naive
    affine evaluation can dip or jump by an ulp."""
    curves = list(slo_mix(12, horizon=77.7).values())
    curves.append(ValueCurve.linear_decay(1e-3, 1e3 + 1e-7))
    curves.append(ValueCurve.exponential(13.0, segments=3))
    for c in curves:
        probes = [0.0]
        for b in c.breaks:
            probes += [
                math.nextafter(b, -math.inf),
                b,
                math.nextafter(b, math.inf),
            ]
            probes += [b * 0.5, b * 0.99, b * 1.01]
        probes += [max(c.breaks, default=1.0) * 3.0]
        probes = sorted(p for p in probes if p >= 0.0)
        vals = [c.value(p) for p in probes]
        for lo, hi in zip(vals[1:], vals, strict=False):
            assert lo <= hi, (c, probes)


def test_instance_helpers():
    assert instance_id("kmeans#7") == "7"
    assert instance_id("kmeans") == "0"
    cs = instance_curves([ValueCurve.step(5.0), ValueCurve.step(9.0)])
    assert set(cs) == {"0", "1"} and cs["1"].breaks == (9.0,)
    mix = slo_mix(9, horizon=50.0)
    assert set(mix) == {str(i) for i in range(9)}
    assert len({c for c in mix.values()}) > 3  # deadlines actually spread


# ---------------------------------------------------------------------------
# vos module fixes
# ---------------------------------------------------------------------------


def test_exponential_decay_closed_form():
    assert exponential_decay(0.0, tau=10.0, value=2.0) == 2.0
    assert exponential_decay(10.0, tau=10.0) == pytest.approx(math.exp(-1.0))


def test_system_vos_strict_raises_on_missing_spec():
    r = run_instances(
        ds_workload(), paper_pool(), CostModel(), policy="eft", n_instances=3
    )
    specs = {"0": VoSSpec(1e3, 4e3), "1": VoSSpec(1e3, 4e3)}  # "2" missing
    assert system_vos(r.schedule, specs) > 0.0  # lenient: silently skipped
    with pytest.raises(KeyError, match="strict"):
        system_vos(r.schedule, specs, strict=True)
    # ValueCurve specs are accepted wherever VoSSpec is
    curves = {str(i): ValueCurve.linear_decay(1e3, 4e3) for i in range(3)}
    assert system_vos(r.schedule, curves, strict=True) > 0.0


# ---------------------------------------------------------------------------
# Heterogeneous-curve scheduling: golden + differential pinning
# ---------------------------------------------------------------------------


def test_hetero_vos_matches_golden():
    """The checked-in heterogeneous golden digest was captured from the
    *reference* engine (see benchmarks/capture_golden.py) — the fast
    engine must reproduce it byte-for-byte."""
    with open(GOLDEN) as f:
        g = json.load(f)["vos_hetero_n24"]
    curves = slo_mix(24, horizon=6.0 * 24)
    r = run_instances(
        ds_workload(),
        paper_pool(),
        CostModel(),
        policy="vos",
        n_instances=24,
        curves=curves,
    )
    assert r.makespan == g["makespan"]
    assert r.mean_utilization == g["mean_utilization"]
    assert r.total_energy == g["total_energy"]
    assert assignment_digest(r.schedule.assignments) == g["digest"]


def test_hetero_vos_matches_reference_engine():
    wl = ds_workload()
    pool = paper_pool(n_arm=2, n_xeon=2)
    cost = CostModel()
    curves = slo_mix(10, horizon=80.0)
    merged = merge([wl.instance(i) for i in range(10)], name="x10")
    live = schedule(merged, pool, cost, policy="vos", curves=curves)
    ref = schedule_reference(merged, pool, cost, policy="vos", curves=curves)
    assert _tuples(live) == _tuples(ref)


def test_default_curve_still_matches_reference_engine():
    """No curves given: the pool-derived default must still pin against
    the reference engine (both evaluate through ValueCurve.value now)."""
    wl = ds_workload()
    pool = paper_pool(n_arm=2, n_xeon=2)
    merged = merge([wl.instance(i) for i in range(8)], name="x8")
    live = schedule(merged, pool, CostModel(), policy="vos")
    ref = schedule_reference(merged, pool, CostModel(), policy="vos")
    assert _tuples(live) == _tuples(ref)


def _mix_for(seed: int, n: int, scale: float):
    """Deterministic curve family indexed by a hypothesis seed — mixes the
    three shapes, per-curve energy weights, and deadline spreads."""
    out = {}
    for i in range(n):
        k = (seed + i) % 4
        h = scale * (0.3 + ((seed * 13 + i * 7) % 10) / 5.0)
        ew = 2e-4 if (seed + i) % 3 == 0 else None
        if k == 0:
            out[str(i)] = ValueCurve.linear_decay(h, 3.0 * h, energy_weight=ew)
        elif k == 1:
            out[str(i)] = ValueCurve.step(2.0 * h, value=1.0 + (i % 3))
        elif k == 2:
            out[str(i)] = ValueCurve.exponential(h, horizon=4.0 * h, segments=5)
        # k == 3: no entry — falls back to the pool-derived default
    return out


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    n_instances=st.integers(min_value=2, max_value=8),
    scale=st.floats(min_value=10.0, max_value=200.0),
)
def test_hetero_differential_hypothesis_batch(seed, n_instances, scale):
    """Random SLO mixes (all three shapes + defaulted instances + per-curve
    energy weights): fast engine == reference engine, byte for byte."""
    wl = ds_workload()
    pool = paper_pool(n_arm=2, n_xeon=2)
    cost = CostModel()
    curves = _mix_for(seed, n_instances, scale)
    merged = merge([wl.instance(i) for i in range(n_instances)], name=f"h{seed}")
    live = schedule(merged, pool, cost, policy="vos", curves=curves)
    ref = schedule_reference(merged, pool, cost, policy="vos", curves=curves)
    assert _tuples(live) == _tuples(ref)


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    n_instances=st.integers(min_value=2, max_value=8),
    period=st.floats(min_value=0.0, max_value=15.0),
)
def test_hetero_differential_hypothesis_online(seed, n_instances, period):
    """Random SLO mixes through the streaming driver: deferred admission
    with per-instance floors stays byte-identical to the batch path."""
    wl = ds_workload()
    pool = paper_pool(n_arm=2, n_xeon=2)
    cost = CostModel()
    curves = _mix_for(seed, n_instances, 60.0)
    batch = run_instances(
        wl,
        pool,
        cost,
        policy="vos",
        n_instances=n_instances,
        period=period,
        curves=curves,
    )
    online = run_instances(
        wl,
        pool,
        cost,
        policy="vos",
        n_instances=n_instances,
        period=period,
        online=True,
        curves=curves,
    )
    assert _tuples(online.schedule) == _tuples(batch.schedule)


def test_online_floor_order_beats_arrival_order():
    """A late-arriving high-value instance has a *lower* key floor than
    earlier low-value ones and must jump the admission gate — the case
    where floor order and arrival order genuinely disagree."""
    wl = ds_workload()
    pool = paper_pool()
    cost = CostModel()
    cold = ValueCurve.linear_decay(10.0, 30.0, value=0.2)
    hot = ValueCurve.linear_decay(500.0, 900.0, value=5.0)
    curves = {str(i): (hot if i >= 6 else cold) for i in range(8)}
    batch = run_instances(
        wl, pool, cost, policy="vos", n_instances=8, period=4.0, curves=curves
    )
    drv = OnlineDriver(pool, cost, policy="vos")
    for i in range(8):
        drv.submit(wl.instance(i), arrival_t=i * 4.0, curve=curves[str(i)])
    online = drv.run()
    assert _tuples(online) == _tuples(batch.schedule)


def test_repool_with_curves_matches_restart():
    """Per-instance curves survive the elastic re-plan path: a mid-run
    shrink under a heterogeneous mix completes with exactly the placements
    a restart-from-history (same curve map) makes."""
    wl = ds_workload()
    pool = paper_pool()
    cost = CostModel()
    curves = slo_mix(12, horizon=100.0)
    drv = OnlineDriver(pool, cost, policy="vos", curves=curves)
    for i in range(12):
        drv.submit(wl.instance(i), arrival_t=i * 3.0)
    for _ in range(50):
        assert drv.step() is not None
    history = list(drv.eng.assignments)
    admitted = [(inst.dag, inst.arrival) for inst in drv.instances]
    pending = drv.pending_submissions()
    loc_of = {p.name: p.location for p in pool.pes}
    new_pool = pool.without(["xeon2", "arm1"])
    drv.repool(new_pool)
    a = _tuples(drv.run())
    drv_b = restart_from_history(
        new_pool, cost, "vos", admitted, history, pending, loc_of, curves=curves
    )
    b = _tuples(drv_b.run())
    assert a == b
    assert len(a) == 12 * 16


def test_curve_classes_fold_by_curve():
    """Class grouping keys on the curve: n instances over k distinct SLO
    classes cost k candidate classes per template task, not n."""
    wl = ds_workload()
    a = ValueCurve.step(100.0)
    b = ValueCurve.linear_decay(50.0, 200.0)
    curves = {str(i): (a if i % 2 else b) for i in range(10)}
    merged = merge([wl.instance(i) for i in range(10)], name="x10")
    eng = S._Engine(merged, paper_pool(), CostModel())
    run = S._VosRun(eng, curves=curves)
    run.on_admit(merged)
    sel = run._selector()
    sel.push_ready()
    # sources: one template task x 10 instances, 2 curves -> 2 classes
    sizes = sorted(len(c.members) for c in sel._classes)
    assert sizes == [5, 5]


# ---------------------------------------------------------------------------
# API edges
# ---------------------------------------------------------------------------


def test_legacy_value_fn_is_exclusive_with_curves():
    wl = ds_workload()
    merged = merge([wl.instance(0)], name="x1")
    with pytest.raises(ValueError, match="exclusive"):
        schedule(
            merged,
            paper_pool(),
            CostModel(),
            policy="vos",
            value_fn=lambda t, f: 1.0,
            curves={"0": ValueCurve.step(9.0)},
        )


def test_value_fn_accepts_a_curve_as_default():
    wl = ds_workload()
    pool = paper_pool(n_arm=2, n_xeon=2)
    merged = merge([wl.instance(i) for i in range(4)], name="x4")
    c = ValueCurve.linear_decay(40.0, 160.0)
    with pytest.warns(DeprecationWarning, match="default_curve"):
        via_value_fn = schedule(merged, pool, CostModel(), policy="vos", value_fn=c)
    via_default = schedule(merged, pool, CostModel(), policy="vos", default_curve=c)
    ref = schedule_reference(merged, pool, CostModel(), policy="vos", default_curve=c)
    assert _tuples(via_value_fn) == _tuples(via_default) == _tuples(ref)


def test_submit_curve_requires_vos_policy():
    drv = OnlineDriver(paper_pool(), CostModel(), policy="eft")
    with pytest.raises(ValueError, match="vos"):
        drv.submit(ds_workload().instance(0), curve=ValueCurve.step(10.0))


def test_non_monotone_custom_value_fn_still_rejected():
    wl = ds_workload()
    merged = merge([wl.instance(i) for i in range(3)], name="x3")

    def bad(t, f):
        return f

    with pytest.warns(DeprecationWarning, match="slow path"):
        with pytest.raises(ValueError, match="non-decreasing"):
            schedule(merged, paper_pool(), CostModel(), policy="vos", value_fn=bad)


def test_normalize_curves_accepts_every_spelling():
    from repro.core.vos import normalize_curves

    c0, c1 = ValueCurve.step(5.0), ValueCurve.step(9.0)
    assert normalize_curves(None) is None
    assert normalize_curves({"0": c0, "7": c1}) == {"0": c0, "7": c1}
    assert normalize_curves([c0, c1]) == {"0": c0, "1": c1}
    assert normalize_curves(lambda i: (c0, c1)[i % 2], n_instances=3) == {
        "0": c0,
        "1": c1,
        "2": c0,
    }
    with pytest.raises(TypeError, match="default_curve"):
        normalize_curves(c0)  # a lone curve is not a collection
    with pytest.raises(TypeError, match="instance count"):
        normalize_curves(lambda i: c0)  # callable needs n_instances


def test_tier_ladder_orders_value_and_deadlines():
    from repro.core.vos import TIERS, tier_curve, tier_mix

    unit = 2.0
    ci, cb, ce = (tier_curve(t, unit) for t in TIERS)
    assert ci.value(0.0) > cb.value(0.0) > ce.value(0.0)
    assert ci.hard_deadline() == 4.0 * unit
    assert cb.hard_deadline() == 32.0 * unit
    assert ce.hard_deadline() == math.inf  # best-effort never expires
    mix = tier_mix(10, unit)
    assert set(mix) == {str(i) for i in range(10)}
    counts = {t: 0 for t in TIERS}
    for c in mix.values():
        for t in TIERS:
            if c == tier_curve(t, unit):
                counts[t] += 1
    assert counts == {"interactive": 2, "batch": 5, "best_effort": 3}
    with pytest.raises(ValueError, match="unknown tier"):
        tier_curve("gold")


def test_curves_spelling_unified_across_run_entry_points():
    """run_instances and run_online take the same curves= spellings
    (sequence == mapping) and produce identical vos schedules."""
    from repro.core.online import run_online

    wl = ds_workload()
    pool = paper_pool()
    seq = [
        ValueCurve.step(60.0),
        ValueCurve.linear_decay(30.0, 120.0),
        ValueCurve.constant(0.5),
    ]
    as_map = {str(i): c for i, c in enumerate(seq)}
    r_seq = run_instances(
        wl, pool, CostModel(), policy="vos", n_instances=3, curves=seq
    )
    r_map = run_instances(
        wl, pool, CostModel(), policy="vos", n_instances=3, curves=as_map
    )
    assert _tuples(r_seq.schedule) == _tuples(r_map.schedule)
    r_onl = run_online(wl, pool, CostModel(), policy="vos", n_instances=3, curves=seq)
    assert _tuples(r_onl.schedule) == _tuples(r_seq.schedule)


def test_slo_curves_completes_the_durable_record():
    """Curves attached via submit(curve=...) are policy state: a restart
    without them silently falls back to the default curve. slo_curves()
    is the missing half of the durable record — restarting with it
    reproduces the original run's remaining placements exactly."""
    wl = ds_workload()
    pool = paper_pool()
    cost = CostModel()
    mix = slo_mix(8, horizon=90.0)
    drv = OnlineDriver(pool, cost, policy="vos")
    for i in range(8):
        drv.submit(wl.instance(i), arrival_t=i * 3.0, curve=mix[str(i)])
    for _ in range(40):
        assert drv.step() is not None
    record = (
        [(inst.dag, inst.arrival) for inst in drv.instances],
        list(drv.eng.assignments),
        drv.pending_submissions(),
        drv.slo_curves(),
    )
    a = _tuples(drv.run())
    admitted, history, pend, curves = record
    drv_b = restart_from_history(
        pool, cost, "vos", admitted, history, pend, curves=curves
    )
    assert _tuples(drv_b.run()) == a


def test_add_curve_rejects_instance_id_collision():
    """Two raw DAGs (no '#idx' suffixes) share the implicit instance id
    "0"; attaching different curves would silently re-SLO the first — the
    driver must fail loud instead."""
    from repro.core.dag import PipelineDAG, Task

    def raw(prefix):
        g = PipelineDAG(prefix)
        g.add_task(Task(f"{prefix}_a", "ingest", work=2.0))
        return g

    drv = OnlineDriver(paper_pool(), CostModel(), policy="vos")
    drv.submit(raw("x"), curve=ValueCurve.step(50.0))
    with pytest.raises(ValueError, match="already has a different curve"):
        drv.submit(raw("y"), curve=ValueCurve.step(90.0))
    # re-attaching an equal curve is fine (idempotent)
    drv.submit(raw("z"), curve=ValueCurve.step(50.0))


def test_driver_pending_bookkeeping_stays_bounded():
    """Regression: gate-path admission used to leave every admitted
    (t, seq, dag) tuple in _pending forever — a continuously fed driver
    leaked memory linearly in total submissions."""
    wl = ds_workload()
    drv = OnlineDriver(paper_pool(), CostModel(), policy="eft")
    for i in range(60):
        drv.submit(wl.instance(i), arrival_t=i * 5.0)
    drv.run()
    assert drv.pending == 0
    assert len(drv._pending) == 0
    assert len(drv._dead_pending) == 0
    assert drv.pending_submissions() == []


def test_as_value_fn_is_the_slow_path_of_the_same_curve():
    """The legacy-callable slow path (no grouping, no offset form, no
    deferral) must schedule identically to the structured fast path for
    the same curve — the one differential that pins slow against fast."""
    wl = ds_workload()
    pool = paper_pool(n_arm=2, n_xeon=2)
    merged = merge([wl.instance(i) for i in range(5)], name="x5")
    c = ValueCurve.linear_decay(30.0, 120.0)
    fast = schedule(merged, pool, CostModel(), policy="vos", default_curve=c)
    with pytest.warns(DeprecationWarning, match="slow path"):
        slow = schedule(
            merged, pool, CostModel(), policy="vos", value_fn=c.as_value_fn()
        )
    assert _tuples(fast) == _tuples(slow)


def test_value_batch_bitwise_matches_scalar():
    """value_batch() is the vectorised form of value(): float64-bitwise
    identical per element, across every ctor shape and right at segment
    boundaries (nextafter probes) where the ulp-clamp branch fires."""
    import numpy as np

    curves = list(slo_mix(12, horizon=77.7).values())
    curves += [
        ValueCurve.step(10.0, value=3.0),
        ValueCurve.linear_decay(20.0, 60.0, value=2.0),
        ValueCurve.linear_decay(1e-3, 1e3 + 1e-7),
        ValueCurve.exponential(13.0, value=4.0, segments=16),
        ValueCurve.exponential(13.0, segments=3),
        ValueCurve.constant(1.5),
    ]
    rng = np.random.default_rng(0)
    for c in curves:
        probes = [0.0]
        for b in c.breaks:
            probes += [
                math.nextafter(b, -math.inf),
                b,
                math.nextafter(b, math.inf),
                b * 0.5,
                b * 0.99,
                b * 1.01,
            ]
        hi = max(c.breaks, default=1.0) * 3.0
        probes += [hi] + list(rng.uniform(0.0, hi, size=64))
        probes = sorted(p for p in probes if p >= 0.0)
        got = c.value_batch(probes)
        want = np.array([c.value(p) for p in probes], dtype=np.float64)
        assert got.dtype == np.float64
        # bitwise, not allclose: the batch path must run the same float
        # expressions as the scalar path
        assert np.array_equal(got.view(np.uint64), want.view(np.uint64)), c
        # scalars and 0-d arrays round-trip too
        assert float(c.value_batch(probes[len(probes) // 2])) == c.value(
            probes[len(probes) // 2])
