"""Per-architecture smoke tests (deliverable f).

Every assigned arch: instantiate the REDUCED same-family config, run one
forward and one train step on CPU; assert output shapes + finiteness.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.models import frontends
from repro.models import model as M
from repro.train.optimizer import OptConfig
from repro.train.train_step import build_train_step, init_train_state


def _batch(cfg, B=2, S=16):
    if cfg.family == "audio":
        toks = jnp.asarray(frontends.fake_codec_tokens(cfg, B, S + 1))
    else:
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 2,
                                  cfg.vocab_size)
    b = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "vlm":
        b["vision"] = jnp.asarray(
            frontends.fake_patch_embeddings(cfg, B), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    batch = _batch(cfg)
    B, S = batch["tokens"].shape

    state = init_train_state(cfg, OptConfig(lr=1e-3, total_steps=10),
                             jax.random.PRNGKey(0))
    logits, _, _ = M.forward(cfg, state["params"], batch["tokens"],
                             vision=batch.get("vision"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    step = jax.jit(build_train_step(cfg, OptConfig(lr=1e-3, total_steps=10)))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state2["step"]) == 1
    # params actually changed
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        state["params"], state2["params"])
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", ["gemma2-9b", "mixtral-8x22b",
                                  "falcon-mamba-7b", "jamba-v0.1-52b",
                                  "llama-3.2-vision-11b"])
def test_smoke_greedy_decode(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init(cfg, jax.random.PRNGKey(0))
    vis = (jnp.asarray(frontends.fake_patch_embeddings(cfg, 1), jnp.float32)
           if cfg.family == "vlm" else None)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 2,
                                cfg.vocab_size)
    out = M.greedy_generate(cfg, params, prompt, n_tokens=4, max_seq=32,
                            vision=vis)
    assert out.shape == (1, 4)
    assert bool(((out >= 0) & (out < cfg.vocab_size)).all())
