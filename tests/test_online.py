"""Online arrival driver + elastic re-plan tests (PR 3).

Three pillars:

  * **Batch equivalence** — for any ``period``, the streaming driver
    (repro.core.online) must produce *byte-identical* schedules to the
    batch ``run_instances(period)`` path, for every policy: the admission
    gate defers instances exactly while no task of theirs could win (or
    tie) the next placement. Pinned three ways: against the checked-in
    golden digests, parametrised over policies × periods, and a hypothesis
    differential over random templates/periods/policies.
  * **Elastic re-plan differential** — shrinking or growing the pool
    mid-run via ``OnlineDriver.repool`` must complete with exactly the
    placements a restart-from-history run on the surviving pool makes
    (``restart_from_history``: fresh engine + admissions + replayed
    assignment record). This pins the live re-key path (horizon remaps,
    plan/link drops, selector rebuilds, pool-dependent re-ranking) against
    the from-scratch reconstruction.
  * **Driver runtime behaviour** — instances retire when their last task
    is placed (completions recorded, plan-cache rows freed), the live set
    stays bounded for spaced arrivals, and heterogeneous submissions are
    accepted.
"""

import json
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cost_model import CostModel, LearnedCostModel
from repro.core.dag import PipelineDAG, Task
from repro.core.online import OnlineDriver, restart_from_history, run_online
from repro.core.resources import paper_pool
from repro.core.schedulers import POLICIES, assignment_digest
from repro.core.simulator import run_instances
from repro.pipeline.workloads import ds_workload

GOLDEN = os.path.join(os.path.dirname(__file__), "golden_sched.json")


def _digest(sched):
    return assignment_digest(sched.assignments)


def _assignment_tuples(sched):
    return [(a.task, a.op, a.pe, a.start, a.finish, a.comm_wait, a.energy)
            for a in sched.assignments]


# ---------------------------------------------------------------------------
# Batch equivalence
# ---------------------------------------------------------------------------

def test_online_matches_golden_arrival_pin():
    """The streaming driver reproduces the *seed-engine* golden digest for
    the arrival-period run — three engine generations, one schedule."""
    with open(GOLDEN) as f:
        g = json.load(f)["eft_n10_period7.5"]
    r = run_online(ds_workload(), paper_pool(), CostModel(),
                   policy="eft", n_instances=10, period=7.5)
    assert r.makespan == g["makespan"]
    assert r.mean_utilization == g["mean_utilization"]
    assert r.total_energy == g["total_energy"]
    assert _digest(r.schedule) == g["digest"]


@pytest.mark.parametrize("period", [0.0, 3.0, 7.5])
@pytest.mark.parametrize("policy", POLICIES)
def test_online_matches_batch_all_policies(policy, period):
    wl = ds_workload()
    pool = paper_pool()
    cost = CostModel()
    batch = run_instances(wl, pool, cost, policy=policy, n_instances=8,
                          period=period)
    online = run_instances(wl, pool, cost, policy=policy, n_instances=8,
                           period=period, online=True)
    assert (_assignment_tuples(online.schedule)
            == _assignment_tuples(batch.schedule))
    assert online.makespan == batch.makespan
    assert online.total_energy == batch.total_energy
    assert online.n_events == len(batch.schedule.assignments)


def _random_template(seed: int, n: int = 9) -> PipelineDAG:
    rng = np.random.default_rng(seed)
    g = PipelineDAG(f"tpl{seed}")
    ops = ["ingest", "sql_transform", "kmeans", "summarize", "window_agg",
           "linreg", "anomaly", "export"]
    for i in range(n):
        g.add_task(Task(f"t{i}", str(rng.choice(ops)),
                        work=float(rng.uniform(0.5, 12)),
                        out_bytes=float(rng.uniform(0, 3e6)),
                        in_bytes=float(rng.uniform(0, 6e6)) if i == 0 else 0))
    for i in range(1, n):
        for j in rng.choice(i, size=min(i, 2), replace=False):
            g.add_edge(f"t{j}", f"t{i}")
    return g


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n_instances=st.integers(min_value=1, max_value=10),
       period=st.floats(min_value=0.0, max_value=12.0),
       policy=st.sampled_from(POLICIES))
def test_online_batch_differential_hypothesis(seed, n_instances, period,
                                              policy):
    """Random template × random arrival spacing × every policy: streaming
    driver == batch path, assignment for assignment."""
    wl = _random_template(seed)
    pool = paper_pool(n_arm=2, n_xeon=2)
    cost = CostModel()
    batch = run_instances(wl, pool, cost, policy=policy,
                          n_instances=n_instances, period=period)
    online = run_instances(wl, pool, cost, policy=policy,
                           n_instances=n_instances, period=period,
                           online=True)
    assert (_assignment_tuples(online.schedule)
            == _assignment_tuples(batch.schedule))


def test_online_learned_cost_model_scalar_path():
    """Subclassed cost models disable the vectorized tables (and class
    grouping); the online driver must still match the batch path."""
    def trained():
        m = LearnedCostModel(min_samples=2)
        t = Task("k", "kmeans", work=10.0)
        for pe in paper_pool().pes:
            for _ in range(3):
                m.observe(t, pe, seconds=0.5)
        return m

    wl = ds_workload()
    pool = paper_pool()
    batch = run_instances(wl, pool, trained(), policy="eft", n_instances=6,
                          period=5.0)
    online = run_instances(wl, pool, trained(), policy="eft", n_instances=6,
                           period=5.0, online=True)
    assert (_assignment_tuples(online.schedule)
            == _assignment_tuples(batch.schedule))


# ---------------------------------------------------------------------------
# Elastic re-plan vs restart-from-history
# ---------------------------------------------------------------------------

def _run_split(policy, drop, k, n_instances=12, period=3.0, grow_to=None):
    """Drive ``k`` events, change the pool, finish via (A) live repool and
    (B) restart-from-history; return both assignment-tuple lists."""
    wl = ds_workload()
    pool = paper_pool() if grow_to is None else paper_pool().without(drop)
    cost = CostModel()
    drv = OnlineDriver(pool, cost, policy=policy)
    for i in range(n_instances):
        drv.submit(wl.instance(i), arrival_t=i * period)
    for _ in range(k):
        assert drv.step() is not None
    history = list(drv.eng.assignments)
    admitted = [(inst.dag, inst.arrival) for inst in drv.instances]
    pending = drv.pending_submissions()
    loc_of = {p.name: p.location for p in pool.pes}
    new_pool = grow_to if grow_to is not None else pool.without(drop)
    drv.repool(new_pool)
    sched_a = drv.run()
    drv_b = restart_from_history(new_pool, cost, policy, admitted, history,
                                 pending, loc_of)
    sched_b = drv_b.run()
    return _assignment_tuples(sched_a), _assignment_tuples(sched_b)


@pytest.mark.parametrize("policy", POLICIES)
def test_repool_shrink_matches_restart(policy):
    """Mid-run shrink (PEs removed, some with placed history) completes
    with the placements a restart-from-scratch on the surviving pool
    makes."""
    a, b = _run_split(policy, drop=["xeon2", "arm1"], k=50)
    assert a == b
    assert len(a) == 12 * 16  # every task placed exactly once


@pytest.mark.parametrize("policy", ["eft", "etf", "minmin", "vos"])
def test_repool_whole_location_removed(policy):
    """Removing every frontend PE strands placed history at a location with
    no PEs — transfer plans and link bookings must survive by location."""
    a, b = _run_split(policy, drop=["arm0", "arm1", "arm2", "volta0"], k=64)
    assert a == b


@pytest.mark.parametrize("policy", ["eft", "etf_hwang", "heft", "rr"])
def test_repool_grow_matches_restart(policy):
    """Mid-run grow (a PE joins) re-plans onto the larger pool identically
    to a restart on it."""
    a, b = _run_split(policy, drop=["xeon2"], k=40, n_instances=10,
                      grow_to=paper_pool())
    assert a == b


def test_repool_uses_new_pe():
    """A grow is not cosmetic: remaining work actually lands on the PE that
    joined (it starts free while incumbents carry horizons)."""
    wl = ds_workload()
    small = paper_pool().without(["xeon2"])
    drv = OnlineDriver(small, CostModel(), policy="eft")
    for i in range(8):
        drv.submit(wl.instance(i), arrival_t=0.0)
    for _ in range(40):
        drv.step()
    drv.repool(paper_pool())
    sched = drv.run()
    assert any(a.pe == "xeon2" for a in sched.assignments)


def test_health_monitor_drives_repool():
    """Elastic wiring end-to-end: a dead PE reported by the HealthMonitor
    prunes the pool, the driver re-plans, and no further task lands on the
    dead PE."""
    from repro.core import elastic as el
    wl = ds_workload()
    pool = paper_pool()
    drv = OnlineDriver(pool, CostModel(), policy="eft")
    for i in range(6):
        drv.submit(wl.instance(i), arrival_t=0.0)
    for _ in range(30):
        drv.step()
    mon = el.HealthMonitor([p.name for p in pool.pes], heartbeat_timeout=5.0)
    for p in pool.pes:
        mon.heartbeat(p.name, now=8.0)
    mon.heartbeat("xeon1", now=-100.0)  # silent worker
    for w in mon.dead(now=10.0):
        mon.mark_dead(w)
    assert mon.healthy() == [p.name for p in pool.pes if p.name != "xeon1"]
    n_before = len(drv.eng.assignments)
    drv.repool(el.prune_pool(pool, mon))
    sched = drv.run()
    assert all(a.pe != "xeon1" for a in sched.assignments[n_before:])
    assert len(sched.assignments) == 6 * 16


# ---------------------------------------------------------------------------
# Driver runtime behaviour
# ---------------------------------------------------------------------------

def test_driver_retires_instances_and_bounds_live_set():
    wl = ds_workload()
    # period far above the per-instance service time: the live set must
    # stay tiny no matter how many instances stream through
    r = run_online(wl, paper_pool(), CostModel(), policy="eft",
                   n_instances=30, period=60.0)
    assert [name for name, _ in r.completions] == \
        [f"{wl.name}#{i}" for i in range(30)]
    assert r.max_live <= 3
    assert r.n_events == 30 * 16


def test_driver_frees_plan_cache_on_retire():
    wl = ds_workload()
    drv = OnlineDriver(paper_pool(), CostModel(), policy="eft")
    for i in range(4):
        drv.submit(wl.instance(i), arrival_t=i * 500.0)
    drv.run()
    first = drv.instances[0]
    assert first.completed
    for row in drv.eng._plans.values():
        assert all(row[t] is None for t in range(first.first_tid,
                                                 first.first_tid
                                                 + first.n_tasks))


def test_driver_heterogeneous_submissions():
    """Different DAGs may stream through one driver; every task is placed
    once and never before its instance's arrival."""
    pool = paper_pool()
    drv = OnlineDriver(pool, CostModel(), policy="eft")
    dags = [_random_template(s).instance(s) for s in (1, 2, 3)]
    for i, d in enumerate(dags):
        drv.submit(d, arrival_t=i * 4.0)
    sched = drv.run()
    assert len(sched.assignments) == sum(len(d) for d in dags)
    by_task = {a.task: a for a in sched.assignments}
    for i, d in enumerate(dags):
        for t in d.tasks:
            assert by_task[t.name].start >= i * 4.0
    assert sorted(n for n, _ in drv.completions) == sorted(d.name for d in dags)


def test_driver_rejects_duplicate_admission():
    wl = ds_workload()
    drv = OnlineDriver(paper_pool(), CostModel(), policy="eft")
    drv.submit(wl.instance(0))
    drv.submit(wl.instance(0))
    with pytest.raises(ValueError, match="duplicate task"):
        drv.run()


def test_stepwise_interleaves_with_batch_result():
    """Manual step() loop == run(), and the result object carries the
    batch-compatible aggregate fields."""
    wl = ds_workload()
    pool = paper_pool()
    cost = CostModel()
    drv = OnlineDriver(pool, cost, policy="etf")
    for i in range(5):
        drv.submit(wl.instance(i), arrival_t=i * 7.5)
    placed = []
    while True:
        a = drv.step()
        if a is None and not drv.pending:
            break
        placed.append(a)
    batch = run_instances(wl, pool, cost, policy="etf", n_instances=5,
                          period=7.5)
    assert ([(a.task, a.pe, a.start, a.finish) for a in placed]
            == [(a.task, a.pe, a.start, a.finish)
                for a in batch.schedule.assignments])
    res = drv.result()
    assert res.makespan == batch.makespan
    assert res.policy == "etf"
