"""Online arrival driver + elastic re-plan tests (PR 3).

Three pillars:

  * **Batch equivalence** — for any ``period``, the streaming driver
    (repro.core.online) must produce *byte-identical* schedules to the
    batch ``run_instances(period)`` path, for every policy: the admission
    gate defers instances exactly while no task of theirs could win (or
    tie) the next placement. Pinned three ways: against the checked-in
    golden digests, parametrised over policies × periods, and a hypothesis
    differential over random templates/periods/policies.
  * **Elastic re-plan differential** — shrinking or growing the pool
    mid-run via ``OnlineDriver.repool`` must complete with exactly the
    placements a restart-from-history run on the surviving pool makes
    (``restart_from_history``: fresh engine + admissions + replayed
    assignment record). This pins the live re-key path (horizon remaps,
    plan/link drops, selector rebuilds, pool-dependent re-ranking) against
    the from-scratch reconstruction.
  * **Driver runtime behaviour** — instances retire when their last task
    is placed (completions recorded, plan-cache rows freed), the live set
    stays bounded for spaced arrivals, and heterogeneous submissions are
    accepted.
"""

import heapq
import json
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cost_model import CostModel, LearnedCostModel
from repro.core.dag import PipelineDAG, Task
from repro.core.online import OnlineDriver, restart_from_history, run_online
from repro.core.resources import paper_pool
from repro.core.schedulers import POLICIES, assignment_digest
from repro.core.simulator import run_instances
from repro.core.vos import ValueCurve
from repro.pipeline.workloads import ds_workload

GOLDEN = os.path.join(os.path.dirname(__file__), "golden_sched.json")


def _digest(sched):
    return assignment_digest(sched.assignments)


def _assignment_tuples(sched):
    return [(a.task, a.op, a.pe, a.start, a.finish, a.comm_wait, a.energy)
            for a in sched.assignments]


# ---------------------------------------------------------------------------
# Batch equivalence
# ---------------------------------------------------------------------------

def test_online_matches_golden_arrival_pin():
    """The streaming driver reproduces the *seed-engine* golden digest for
    the arrival-period run — three engine generations, one schedule."""
    with open(GOLDEN) as f:
        g = json.load(f)["eft_n10_period7.5"]
    r = run_online(ds_workload(), paper_pool(), CostModel(),
                   policy="eft", n_instances=10, period=7.5)
    assert r.makespan == g["makespan"]
    assert r.mean_utilization == g["mean_utilization"]
    assert r.total_energy == g["total_energy"]
    assert _digest(r.schedule) == g["digest"]


@pytest.mark.parametrize("period", [0.0, 3.0, 7.5])
@pytest.mark.parametrize("policy", POLICIES)
def test_online_matches_batch_all_policies(policy, period):
    wl = ds_workload()
    pool = paper_pool()
    cost = CostModel()
    batch = run_instances(wl, pool, cost, policy=policy, n_instances=8,
                          period=period)
    online = run_instances(wl, pool, cost, policy=policy, n_instances=8,
                           period=period, online=True)
    assert (_assignment_tuples(online.schedule)
            == _assignment_tuples(batch.schedule))
    assert online.makespan == batch.makespan
    assert online.total_energy == batch.total_energy
    assert online.n_events == len(batch.schedule.assignments)


def _random_template(seed: int, n: int = 9) -> PipelineDAG:
    rng = np.random.default_rng(seed)
    g = PipelineDAG(f"tpl{seed}")
    ops = ["ingest", "sql_transform", "kmeans", "summarize", "window_agg",
           "linreg", "anomaly", "export"]
    for i in range(n):
        g.add_task(Task(f"t{i}", str(rng.choice(ops)),
                        work=float(rng.uniform(0.5, 12)),
                        out_bytes=float(rng.uniform(0, 3e6)),
                        in_bytes=float(rng.uniform(0, 6e6)) if i == 0 else 0))
    for i in range(1, n):
        for j in rng.choice(i, size=min(i, 2), replace=False):
            g.add_edge(f"t{j}", f"t{i}")
    return g


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n_instances=st.integers(min_value=1, max_value=10),
       period=st.floats(min_value=0.0, max_value=12.0),
       policy=st.sampled_from(POLICIES))
def test_online_batch_differential_hypothesis(seed, n_instances, period,
                                              policy):
    """Random template × random arrival spacing × every policy: streaming
    driver == batch path, assignment for assignment."""
    wl = _random_template(seed)
    pool = paper_pool(n_arm=2, n_xeon=2)
    cost = CostModel()
    batch = run_instances(wl, pool, cost, policy=policy,
                          n_instances=n_instances, period=period)
    online = run_instances(wl, pool, cost, policy=policy,
                           n_instances=n_instances, period=period,
                           online=True)
    assert (_assignment_tuples(online.schedule)
            == _assignment_tuples(batch.schedule))


def test_online_learned_cost_model_scalar_path():
    """Subclassed cost models disable the vectorized tables (and class
    grouping); the online driver must still match the batch path."""
    def trained():
        m = LearnedCostModel(min_samples=2)
        t = Task("k", "kmeans", work=10.0)
        for pe in paper_pool().pes:
            for _ in range(3):
                m.observe(t, pe, seconds=0.5)
        return m

    wl = ds_workload()
    pool = paper_pool()
    batch = run_instances(wl, pool, trained(), policy="eft", n_instances=6,
                          period=5.0)
    online = run_instances(wl, pool, trained(), policy="eft", n_instances=6,
                           period=5.0, online=True)
    assert (_assignment_tuples(online.schedule)
            == _assignment_tuples(batch.schedule))


# ---------------------------------------------------------------------------
# Elastic re-plan vs restart-from-history
# ---------------------------------------------------------------------------

def _run_split(policy, drop, k, n_instances=12, period=3.0, grow_to=None):
    """Drive ``k`` events, change the pool, finish via (A) live repool and
    (B) restart-from-history; return both assignment-tuple lists."""
    wl = ds_workload()
    pool = paper_pool() if grow_to is None else paper_pool().without(drop)
    cost = CostModel()
    drv = OnlineDriver(pool, cost, policy=policy)
    for i in range(n_instances):
        drv.submit(wl.instance(i), arrival_t=i * period)
    for _ in range(k):
        assert drv.step() is not None
    history = list(drv.eng.assignments)
    admitted = [(inst.dag, inst.arrival) for inst in drv.instances]
    pending = drv.pending_submissions()
    loc_of = {p.name: p.location for p in pool.pes}
    new_pool = grow_to if grow_to is not None else pool.without(drop)
    drv.repool(new_pool)
    sched_a = drv.run()
    drv_b = restart_from_history(new_pool, cost, policy, admitted, history,
                                 pending, loc_of)
    sched_b = drv_b.run()
    return _assignment_tuples(sched_a), _assignment_tuples(sched_b)


@pytest.mark.parametrize("policy", POLICIES)
def test_repool_shrink_matches_restart(policy):
    """Mid-run shrink (PEs removed, some with placed history) completes
    with the placements a restart-from-scratch on the surviving pool
    makes."""
    a, b = _run_split(policy, drop=["xeon2", "arm1"], k=50)
    assert a == b
    assert len(a) == 12 * 16  # every task placed exactly once


@pytest.mark.parametrize("policy", ["eft", "etf", "minmin", "vos"])
def test_repool_whole_location_removed(policy):
    """Removing every frontend PE strands placed history at a location with
    no PEs — transfer plans and link bookings must survive by location."""
    a, b = _run_split(policy, drop=["arm0", "arm1", "arm2", "volta0"], k=64)
    assert a == b


@pytest.mark.parametrize("policy", ["eft", "etf_hwang", "heft", "rr"])
def test_repool_grow_matches_restart(policy):
    """Mid-run grow (a PE joins) re-plans onto the larger pool identically
    to a restart on it."""
    a, b = _run_split(policy, drop=["xeon2"], k=40, n_instances=10,
                      grow_to=paper_pool())
    assert a == b


def test_repool_uses_new_pe():
    """A grow is not cosmetic: remaining work actually lands on the PE that
    joined (it starts free while incumbents carry horizons)."""
    wl = ds_workload()
    small = paper_pool().without(["xeon2"])
    drv = OnlineDriver(small, CostModel(), policy="eft")
    for i in range(8):
        drv.submit(wl.instance(i), arrival_t=0.0)
    for _ in range(40):
        drv.step()
    drv.repool(paper_pool())
    sched = drv.run()
    assert any(a.pe == "xeon2" for a in sched.assignments)


def test_health_monitor_drives_repool():
    """Elastic wiring end-to-end: a dead PE reported by the HealthMonitor
    prunes the pool, the driver re-plans, and no further task lands on the
    dead PE."""
    from repro.core import elastic as el
    wl = ds_workload()
    pool = paper_pool()
    drv = OnlineDriver(pool, CostModel(), policy="eft")
    for i in range(6):
        drv.submit(wl.instance(i), arrival_t=0.0)
    for _ in range(30):
        drv.step()
    mon = el.HealthMonitor([p.name for p in pool.pes], heartbeat_timeout=5.0)
    for p in pool.pes:
        mon.heartbeat(p.name, now=8.0)
    mon.heartbeat("xeon1", now=-100.0)  # silent worker
    for w in mon.dead(now=10.0):
        mon.mark_dead(w)
    assert mon.healthy() == [p.name for p in pool.pes if p.name != "xeon1"]
    n_before = len(drv.eng.assignments)
    drv.repool(el.prune_pool(pool, mon))
    sched = drv.run()
    assert all(a.pe != "xeon1" for a in sched.assignments[n_before:])
    assert len(sched.assignments) == 6 * 16


# ---------------------------------------------------------------------------
# Driver runtime behaviour
# ---------------------------------------------------------------------------

def test_driver_retires_instances_and_bounds_live_set():
    wl = ds_workload()
    # period far above the per-instance service time: the live set must
    # stay tiny no matter how many instances stream through
    r = run_online(wl, paper_pool(), CostModel(), policy="eft",
                   n_instances=30, period=60.0)
    assert [name for name, _ in r.completions] == \
        [f"{wl.name}#{i}" for i in range(30)]
    assert r.max_live <= 3
    assert r.n_events == 30 * 16


def test_driver_frees_plan_cache_on_retire():
    wl = ds_workload()
    drv = OnlineDriver(paper_pool(), CostModel(), policy="eft")
    for i in range(4):
        drv.submit(wl.instance(i), arrival_t=i * 500.0)
    drv.run()
    first = drv.instances[0]
    assert first.completed
    for row in drv.eng._plans.values():
        assert all(row[t] is None for t in range(first.first_tid,
                                                 first.first_tid
                                                 + first.n_tasks))


def test_driver_heterogeneous_submissions():
    """Different DAGs may stream through one driver; every task is placed
    once and never before its instance's arrival."""
    pool = paper_pool()
    drv = OnlineDriver(pool, CostModel(), policy="eft")
    dags = [_random_template(s).instance(s) for s in (1, 2, 3)]
    for i, d in enumerate(dags):
        drv.submit(d, arrival_t=i * 4.0)
    sched = drv.run()
    assert len(sched.assignments) == sum(len(d) for d in dags)
    by_task = {a.task: a for a in sched.assignments}
    for i, d in enumerate(dags):
        for t in d.tasks:
            assert by_task[t.name].start >= i * 4.0
    assert sorted(n for n, _ in drv.completions) == sorted(d.name for d in dags)


def test_driver_rejects_duplicate_admission():
    wl = ds_workload()
    drv = OnlineDriver(paper_pool(), CostModel(), policy="eft")
    drv.submit(wl.instance(0))
    drv.submit(wl.instance(0))
    with pytest.raises(ValueError, match="duplicate task"):
        drv.run()


def test_stepwise_interleaves_with_batch_result():
    """Manual step() loop == run(), and the result object carries the
    batch-compatible aggregate fields."""
    wl = ds_workload()
    pool = paper_pool()
    cost = CostModel()
    drv = OnlineDriver(pool, cost, policy="etf")
    for i in range(5):
        drv.submit(wl.instance(i), arrival_t=i * 7.5)
    placed = []
    while True:
        a = drv.step()
        if a is None and not drv.pending:
            break
        placed.append(a)
    batch = run_instances(wl, pool, cost, policy="etf", n_instances=5,
                          period=7.5)
    assert ([(a.task, a.pe, a.start, a.finish) for a in placed]
            == [(a.task, a.pe, a.start, a.finish)
                for a in batch.schedule.assignments])
    res = drv.result()
    assert res.makespan == batch.makespan
    assert res.policy == "etf"


# ---------------------------------------------------------------------------
# Batched admission (PR 9)
# ---------------------------------------------------------------------------

class _SerialAdmissionDriver(OnlineDriver):
    """Reference driver with the pre-batching serial admission loop: pop
    one gate entry, re-peek, pop the next. The batched sweep in
    ``OnlineDriver._admit_due`` may admit a whole ``floor <= best``
    prefix against one peek — these differentials pin that the resulting
    *placements* are byte-identical anyway."""

    def _admit_due(self):
        pol = self.policy
        eng = self.eng
        while self._n_pending:
            if not (pol.deferrable and eng._ready):
                t, seq, dag = self._pop_earliest()
                if self._gate is not None:
                    self._dead_gate.add(seq)
                self._n_pending -= 1
                self._admit_now(dag, t)
                continue
            gate = self._gate
            if gate is None:
                gate = self._gate = []
                self._dead_gate.clear()
                dead = self._dead_pending
                for t, seq, dag in self._pending:
                    if seq not in dead:
                        heapq.heappush(
                            gate, (pol.arrival_floor(t, dag), t, seq, dag))
            dead_gate = self._dead_gate
            while gate and gate[0][2] in dead_gate:
                dead_gate.discard(heapq.heappop(gate)[2])
            if not gate:
                break
            floor, t, seq, dag = gate[0]
            best = pol.peek_time()
            if best is not None and floor > best:
                break
            heapq.heappop(gate)
            self._dead_pending.add(seq)
            self._drain_pending()
            self._n_pending -= 1
            self._admit_now(dag, t)


def _bursty_ts(n, seed, mean_gap=4.0, max_burst=6):
    """Tiny deterministic bursty trace: coincident Zipf bursts separated
    by Pareto gaps (the shape the scale benchmark uses)."""
    rng = np.random.default_rng(seed)
    ts, t = [], 0.0
    while len(ts) < n:
        k = int(min(rng.zipf(2.0), max_burst))
        t += mean_gap * (float(rng.pareto(1.5)) + 0.1)
        ts.extend([t] * k)
    return ts[:n]


@pytest.mark.parametrize("policy", POLICIES)
def test_batched_admission_matches_serial(policy):
    """Bursty coincident arrivals through the batched gate == the serial
    one-at-a-time reference, for every policy."""
    wl = ds_workload()
    cost = CostModel()
    ts = _bursty_ts(10, seed=5)
    scheds = {}
    for cls in (OnlineDriver, _SerialAdmissionDriver):
        drv = cls(paper_pool(), cost, policy=policy)
        for i, at in enumerate(ts):
            drv.submit(wl.instance(i), arrival_t=at)
        scheds[cls] = (drv, drv.run())
    assert (_assignment_tuples(scheds[OnlineDriver][1])
            == _assignment_tuples(scheds[_SerialAdmissionDriver][1]))


@pytest.mark.parametrize("policy", POLICIES)
def test_coincident_burst_drains_in_one_sweep(policy):
    """k coincident arrivals: the batched driver must actually batch
    (telemetry counter) and still match the serial reference."""
    wl = ds_workload()
    cost = CostModel()
    drvs = {}
    for cls in (OnlineDriver, _SerialAdmissionDriver):
        drv = cls(paper_pool(), cost, policy=policy)
        for i in range(8):
            drv.submit(wl.instance(i), arrival_t=0.0)
        drvs[cls] = (drv, drv.run())
    drv_b, sched_b = drvs[OnlineDriver]
    assert (_assignment_tuples(sched_b)
            == _assignment_tuples(drvs[_SerialAdmissionDriver][1]))
    assert drv_b.n_batched_steps >= 1
    assert drvs[_SerialAdmissionDriver][0].n_batched_steps == 0
    assert drv_b.result().n_batched_steps == drv_b.n_batched_steps


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       policy=st.sampled_from(POLICIES))
def test_batched_admission_differential_hypothesis(seed, policy):
    """Random template x random bursty trace x every policy: batched
    admission == serial admission, assignment for assignment."""
    wl = _random_template(seed)
    pool = paper_pool(n_arm=2, n_xeon=2)
    cost = CostModel()
    ts = _bursty_ts(8, seed=seed + 1)
    out = []
    for cls in (OnlineDriver, _SerialAdmissionDriver):
        drv = cls(pool, cost, policy=policy)
        for i, at in enumerate(ts):
            drv.submit(wl.instance(i), arrival_t=at)
        out.append(_assignment_tuples(drv.run()))
    assert out[0] == out[1]


def test_batched_drain_value_order_mid_drain():
    """A later-submitted pending instance with a hotter curve outranks an
    earlier one inside a single coincident-burst sweep: the drain is
    floor-ordered, not submit-ordered, and matches the serial gate."""
    wl = ds_workload()
    cost = CostModel()
    cold = ValueCurve.linear_decay(10.0, 30.0, value=0.2)
    hot = ValueCurve.linear_decay(500.0, 900.0, value=5.0)
    curves = [cold, cold, hot, cold, hot]
    out = []
    for cls in (OnlineDriver, _SerialAdmissionDriver):
        drv = cls(paper_pool(), cost, policy="vos")
        for i, c in enumerate(curves):
            drv.submit(wl.instance(i), arrival_t=0.0, curve=c)
        out.append((drv, _assignment_tuples(drv.run())))
    assert out[0][1] == out[1][1]
    # the hot instances' first tasks beat every cold instance's
    first_of = {}
    for tup in out[0][1]:
        inst = tup[0].rsplit("#", 1)[1]
        first_of.setdefault(inst, len(first_of))
    assert max(first_of["2"], first_of["4"]) < min(
        first_of["0"], first_of["1"], first_of["3"])


@pytest.mark.parametrize("policy", ["eft", "etf", "vos"])
def test_batch_spans_fail_boundary(policy):
    """A failure lands while coincident bursts are still pending: the
    continued run (batched re-admissions included) must equal a restart
    on the durable record."""
    wl = ds_workload()
    cost = CostModel()
    drv = OnlineDriver(paper_pool(), cost, policy=policy)
    ts = [0.0] * 4 + [30.0] * 4 + [1e5] * 4
    for i, at in enumerate(ts):
        drv.submit(wl.instance(i), arrival_t=at)
    for _ in range(20):
        assert drv.step() is not None
    t_fail = max(a.start for a in drv.eng.assignments)
    drv.fail(t_fail, ["xeon1"])
    history = list(drv.eng.assignments)
    admitted = [(inst.dag, inst.arrival) for inst in drv.instances]
    pending = drv.pending_submissions()
    assert pending  # the far-future burst is still pending at the fail
    loc_of = dict(drv._loc_of)
    floors = dict(drv.retry_floors)
    cancelled = list(drv.cancelled_instances)
    sched_a = drv.run()
    drv_b = restart_from_history(drv.pool, cost, policy, admitted, history,
                                 pending, loc_of, retry_floors=floors,
                                 cancelled=cancelled)
    assert _assignment_tuples(sched_a) == _assignment_tuples(drv_b.run())


@pytest.mark.parametrize("policy", ["eft", "etf_hwang", "minmin"])
def test_batch_spans_repool_boundary(policy):
    """A mid-run shrink with coincident bursts still pending: batched
    re-admission after the rebind equals restart-from-history."""
    wl = ds_workload()
    cost = CostModel()
    pool = paper_pool()
    drv = OnlineDriver(pool, cost, policy=policy)
    ts = [0.0] * 5 + [25.0] * 5 + [5e4] * 2
    for i, at in enumerate(ts):
        drv.submit(wl.instance(i), arrival_t=at)
    for _ in range(30):
        assert drv.step() is not None
    history = list(drv.eng.assignments)
    admitted = [(inst.dag, inst.arrival) for inst in drv.instances]
    pending = drv.pending_submissions()
    loc_of = {p.name: p.location for p in pool.pes}
    new_pool = pool.without(["xeon2", "arm1"])
    drv.repool(new_pool)
    sched_a = drv.run()
    drv_b = restart_from_history(new_pool, cost, policy, admitted, history,
                                 pending, loc_of)
    assert _assignment_tuples(sched_a) == _assignment_tuples(drv_b.run())


# ---------------------------------------------------------------------------
# Value-aware preemption (PR 9)
# ---------------------------------------------------------------------------

def _preempt_setup(n_cold=2, policy="vos"):
    wl = ds_workload()
    cost = CostModel()
    drv = OnlineDriver(paper_pool(), cost, policy=policy)
    cold = ValueCurve.linear_decay(2e4, 9e4, value=0.2)
    for i in range(n_cold):
        drv.submit(wl.instance(i), arrival_t=0.0, curve=cold)
    for _ in range(12):
        assert drv.step() is not None
    return wl, cost, drv


def test_preemption_displaces_low_value_running_task():
    wl, cost, drv = _preempt_setup()
    a = drv.eng.assignments[-1]
    t = (a.start + a.finish) / 2.0  # mid-flight for at least one task
    hot = ValueCurve.linear_decay(t + 5e4, t + 9e4, value=50.0)
    n_before = len(drv.eng.assignments)
    rep = drv.admit_preempting(wl.instance(7), t, curve=hot)
    assert rep.victim is not None
    assert rep.victim_value < rep.arrival_value
    assert rep.victim in rep.displaced
    assert rep.resume_floor == t + rep.checkpoint_seconds + rep.restore_seconds
    # the victim's booking is vacated from the live record
    assert all(x.task != rep.victim for x in drv.eng.assignments)
    assert len(drv.eng.assignments) < n_before
    # priced resubmission, not a failure
    assert drv.recoveries == []
    assert drv.retry_floors[rep.victim] == rep.resume_floor
    assert drv.n_preemptions == 1
    assert drv.n_displaced == len(rep.displaced) >= 1
    # the checkpoint write occupies the victim's PE (durable raise event)
    assert drv.horizon_events and drv.horizon_events[-1][1] == "raise"
    assert drv.horizon_events[-1][2] == {rep.victim_pe: t
                                         + rep.checkpoint_seconds}
    sched = drv.run()
    names = [x.task for x in sched.assignments]
    assert sorted(names) == sorted(set(names))
    # every task placed exactly once in the final record, and the victim
    # restarts no earlier than its priced resume floor
    victim_a = next(x for x in sched.assignments if x.task == rep.victim)
    assert victim_a.start >= rep.resume_floor - 1e-9
    res = drv.result()
    assert res.n_preemptions == 1
    assert res.n_displaced == len(rep.displaced)


def test_preemption_restart_differential():
    """Continuing after a preempting admission == restart_from_history on
    the durable record (floors + horizon events + curves)."""
    wl, cost, drv = _preempt_setup()
    a = drv.eng.assignments[-1]
    t = (a.start + a.finish) / 2.0
    hot = ValueCurve.linear_decay(t + 5e4, t + 9e4, value=50.0)
    rep = drv.admit_preempting(wl.instance(7), t, curve=hot)
    assert rep.victim is not None
    history = list(drv.eng.assignments)
    admitted = [(inst.dag, inst.arrival) for inst in drv.instances]
    pending = drv.pending_submissions()
    loc_of = dict(drv._loc_of)
    floors = dict(drv.retry_floors)
    events = list(drv.horizon_events)
    curves = drv.slo_curves()
    sched_a = drv.run()
    drv_b = restart_from_history(drv.pool, cost, "vos", admitted, history,
                                 pending, loc_of, retry_floors=floors,
                                 horizon_events=events, curves=curves)
    assert _assignment_tuples(sched_a) == _assignment_tuples(drv_b.run())


def test_preemption_no_victim_falls_through_to_submit():
    """An arrival that outranks nothing degrades to a plain gated submit:
    byte-identical to a driver that never called admit_preempting."""
    wl, cost, drv = _preempt_setup()
    t = max(x.finish for x in drv.eng.assignments) + 100.0  # nothing in flight
    lukewarm = ValueCurve.linear_decay(t + 5e4, t + 9e4, value=0.3)
    rep = drv.admit_preempting(wl.instance(7), t, curve=lukewarm)
    assert rep.victim is None and rep.displaced == ()
    assert drv.n_preemptions == 0 and drv.n_displaced == 0
    assert drv.horizon_events == [] and drv.recoveries == []
    sched_a = drv.run()

    _, _, drv_c = _preempt_setup()
    drv_c.submit(wl.instance(7), arrival_t=t, curve=lukewarm)
    assert _assignment_tuples(sched_a) == _assignment_tuples(drv_c.run())


def test_preemption_requires_structured_vos():
    wl = ds_workload()
    drv = OnlineDriver(paper_pool(), CostModel(), policy="eft")
    drv.submit(wl.instance(0), arrival_t=0.0)
    with pytest.raises(ValueError, match="vos"):
        drv.admit_preempting(wl.instance(1), 1.0)


def test_preemption_racing_site_partition():
    """A preempting admission landing while the edge<->DC link is cut:
    the victim search only sees the (floored) live record, the checkpoint
    raise composes with the partition's defer floors in the durable event
    log, and the combined state restarts byte-identically."""
    from repro.core.federation import paper_federation

    fed = paper_federation(n_arm=2, n_xeon=2)
    cost = CostModel(data_home=fed.data_home)
    drv = OnlineDriver(fed, cost, policy="vos")
    wl = ds_workload()
    cold = ValueCurve.linear_decay(2e4, 9e4, value=0.2)
    for i in range(2):
        drv.submit(wl.instance(i), arrival_t=0.0, curve=cold)
    for _ in range(10):
        assert drv.step() is not None
    a = drv.eng.assignments[-1]
    t_cut = (a.start + a.finish) / 2.0
    drv.partition(t_cut, "dc", defer="all")
    assert "dc" in drv._partition_saved
    t = t_cut + 1.0
    hot = ValueCurve.linear_decay(t + 5e4, t + 9e4, value=50.0)
    rep = drv.admit_preempting(wl.instance(7), t, curve=hot)
    assert rep.victim is not None
    assert drv.n_preemptions == 1
    # both the partition's defer events and the checkpoint raise are in
    # the durable log; the raise is the most recent entry
    assert drv.horizon_events[-1][1] == "raise"
    assert drv.horizon_events[-1][2] == {rep.victim_pe: t
                                         + rep.checkpoint_seconds}
    history = list(drv.eng.assignments)
    admitted = [(inst.dag, inst.arrival) for inst in drv.instances]
    pending = drv.pending_submissions()
    loc_of = dict(drv._loc_of)
    floors = dict(drv.retry_floors)
    events = list(drv.horizon_events)
    curves = drv.slo_curves()
    sched_a = drv.run()
    names = [x.task for x in sched_a.assignments]
    assert sorted(names) == sorted(set(names))
    victim_a = next(x for x in sched_a.assignments if x.task == rep.victim)
    assert victim_a.start >= rep.resume_floor - 1e-9
    drv_b = restart_from_history(fed, cost, "vos", admitted, history,
                                 pending, loc_of, retry_floors=floors,
                                 horizon_events=events, curves=curves)
    assert _assignment_tuples(sched_a) == _assignment_tuples(drv_b.run())


# ---------------------------------------------------------------------------
# Vectorised rank math (PR 9)
# ---------------------------------------------------------------------------

def test_rank_vectorized_bitwise_matches_scalar():
    """The NumPy upward-rank fast path must be *bitwise* identical to the
    scalar recurrence it replaces — it feeds selector keys, so an ulp of
    drift would change placements. Probed over random templates and both
    single-site and federated pools (the latter exercises the mean-comm
    cross-location accumulation)."""
    from repro.core.federation import paper_federation
    from repro.core.schedulers import _rank, _rank_scalar

    pools = [paper_pool(), paper_pool(n_arm=2, n_xeon=2),
             paper_federation(n_arm=2, n_xeon=2).flatten()]
    dags = [ds_workload()] + [_random_template(s) for s in range(6)]
    cost = CostModel()
    checked = 0
    for pool in pools:
        for dag in dags:
            got = _rank(dag, pool, cost)
            want = _rank_scalar(dag, pool, cost)
            assert got.keys() == want.keys()
            for k in want:
                assert got[k] == want[k], (k, got[k].hex(), want[k].hex())
            checked += len(want)
    assert checked > 0
    # subclassed cost models take the scalar path (exact, by definition)
    lc = LearnedCostModel()
    dag = dags[1]
    assert _rank(dag, pools[0], lc) == _rank_scalar(dag, pools[0], lc)
