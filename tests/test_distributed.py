"""Distributed layer: sharding rules, collectives, sharded e2e step.

These need >1 device, so each case runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count set (the main test
process keeps the single real CPU device, per the dry-run contract).
"""

import os
import subprocess
import sys
import textwrap


SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, r.stderr[-4000:]
    return r.stdout


def test_strategy_and_param_specs_divisibility():
    out = run_sub("""
        import jax, json, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_config
        from repro.distributed import sharding as sh
        from repro.models import model as M

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        # musicgen: 6 heads % 4 != 0 → attention replicated, d_ff sharded
        cfg = get_config("musicgen-medium", smoke=True)
        rules = sh.strategy_for(cfg, mesh)
        assert rules.rules["heads"] is None, rules.rules
        assert rules.rules["d_ff"] == "model"
        assert "not divisible" in rules.notes

        # qwen3 smoke: 4 heads % 4 == 0 → sharded
        cfg2 = get_config("qwen3-0.6b", smoke=True)
        rules2 = sh.strategy_for(cfg2, mesh)
        assert rules2.rules["heads"] == "model"
        params = jax.eval_shape(lambda: M.init(cfg2, jax.random.PRNGKey(0)))
        with sh.logical_axis_rules(rules2):
            specs = sh.param_specs(params)
        flat = jax.tree_util.tree_flatten_with_path(specs)[0]
        d = {jax.tree_util.keystr(p): s for p, s in flat}
        assert d["['embed']['embedding']"] == P("model", None)
        wq = [v for k, v in d.items() if "attn']['wq" in k][0]
        assert wq == P("layers", None, "model") or wq == P(None, None, "model"), wq
        # batch-1 fallback: long-context batch of 1 can't shard over data
        spec1 = rules2.spec(("batch", None), (1, 8))
        assert spec1 == P(None, None)
        print("OK")
    """)
    assert "OK" in out


def test_hierarchical_psum_equals_flat():
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.distributed.compat import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.distributed.collectives import hierarchical_psum
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (8, 33)),
                        jnp.float32)
        f1 = shard_map(lambda v: jax.lax.psum(v, ("pod", "data")),
                           mesh=mesh, in_specs=P(), out_specs=P(),
                           check_vma=False)(x)
        f2 = shard_map(lambda v: hierarchical_psum(v), mesh=mesh,
                           in_specs=P(), out_specs=P(), check_vma=False)(x)
        assert float(jnp.abs(f1 - f2).max()) < 1e-4
        print("OK")
    """)
    assert "OK" in out


def test_int8_allreduce_accuracy_and_error_feedback():
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.distributed.compat import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.distributed.collectives import int8_allreduce
        mesh = jax.make_mesh((8,), ("data",))
        vals = jnp.asarray(np.random.default_rng(1).normal(0, 1, (8, 1000)),
                           jnp.float32)
        ref = shard_map(lambda v: jax.lax.pmean(v, "data"), mesh=mesh,
                            in_specs=P("data"), out_specs=P("data"),
                            check_vma=False)(vals)
        def comp(v, e):
            out, e2 = int8_allreduce(v[0], axis="data", error=e[0])
            return out[None], e2[None]
        out, err = shard_map(comp, mesh=mesh,
                                 in_specs=(P("data"), P("data")),
                                 out_specs=(P("data"), P("data")),
                                 check_vma=False)(vals, jnp.zeros_like(vals))
        rel = float(jnp.abs(out - ref).max() / jnp.abs(ref).max())
        assert rel < 0.02, rel
        assert float(jnp.abs(err).max()) > 0      # residual captured
        print("OK")
    """)
    assert "OK" in out


def test_sharded_train_step_matches_single_device():
    """The same train step, sharded over an 8-device (4 data × 2 model)
    mesh, must produce the same loss trajectory as unsharded execution."""
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.distributed.compat import set_mesh
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.distributed import sharding as sh
        from repro.train.optimizer import OptConfig
        from repro.train.train_step import build_train_step, init_train_state

        cfg = get_config("qwen3-0.6b", smoke=True)
        oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        state = init_train_state(cfg, oc, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 2,
                                  cfg.vocab_size)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        step = build_train_step(cfg, oc, remat=False)

        # single device
        s1, m1 = jax.jit(step)(state, batch)

        # sharded
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rules = sh.strategy_for(cfg, mesh)
        with sh.logical_axis_rules(rules):
            st_sh = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), sh.param_specs(state),
                is_leaf=lambda x: isinstance(x, P))
            b_sh = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), sh.batch_specs(batch),
                is_leaf=lambda x: isinstance(x, P))
            def fn(s, b):
                with sh.logical_axis_rules(rules):
                    return step(s, b)
            with set_mesh(mesh):
                s2, m2 = jax.jit(fn, in_shardings=(st_sh, b_sh),
                                 out_shardings=(st_sh, None))(state, batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4, \\
            (float(m1["loss"]), float(m2["loss"]))
        d = jax.tree_util.tree_map(
            lambda a, b: float(jnp.abs(np.asarray(a, np.float32)
                                       - np.asarray(b, np.float32)).max()),
            s1["params"], s2["params"])
        assert max(jax.tree_util.tree_leaves(d)) < 1e-4
        print("OK")
    """)
    assert "OK" in out
