"""Federation topology layer: flatten pins, data gravity, WAN traffic,
cross-site VDC composition, site-aware pruning.

The load-bearing invariant: the engine is *extended, not forked*. A
federation's :meth:`FederatedPool.flatten` must schedule byte-identically
to the equivalent flat pool — for the paper's two-site deployment
(``paper_federation().flatten()`` vs ``paper_pool()``) and for a
single-site federation — under every policy, pinned against the frozen
reference engine.
"""

import numpy as np
import pytest

from repro.core.cost_model import CostModel
from repro.core.dag import PipelineDAG, Task, merge
from repro.core.elastic import HealthMonitor, prune_pool
from repro.core.federation import (WAN_CLASSES, FederatedPool, Site, WANLink,
                                   paper_federation, wan_traffic)
from repro.core.online import OnlineDriver
from repro.core.resources import (BACKEND, FRONTEND, Link, ProcessingElement,
                                  paper_pool)
from repro.core.schedulers import POLICIES, Assignment, schedule
from repro.core.schedulers_reference import schedule_reference
from repro.pipeline.workloads import ds_workload


def _tuples(sched):
    return [(a.task, a.op, a.pe, a.start, a.finish, a.comm_wait, a.energy)
            for a in sched.assignments]


def _random_dag(seed: int, n: int = 14) -> PipelineDAG:
    rng = np.random.default_rng(seed)
    g = PipelineDAG(f"rnd{seed}")
    ops = ["ingest", "sql_transform", "kmeans", "summarize", "window_agg",
           "linreg", "anomaly", "export"]
    for i in range(n):
        g.add_task(Task(f"t{i}", str(rng.choice(ops)),
                        work=float(rng.uniform(0.5, 20)),
                        out_bytes=float(rng.uniform(0, 4e6)),
                        in_bytes=float(rng.uniform(0, 8e6)) if i < 2 else 0))
    for i in range(1, n):
        for j in rng.choice(i, size=min(i, 2), replace=False):
            g.add_edge(f"t{j}", f"t{i}")
    return g


# ---------------------------------------------------------------------------
# Flatten pins: federation == flat pool, byte for byte
# ---------------------------------------------------------------------------

def test_paper_federation_flattens_to_paper_pool():
    flat = paper_federation().flatten()
    ref = paper_pool()
    assert [p.name for p in flat.pes] == [p.name for p in ref.pes]
    assert [p.location for p in flat.pes] == [p.location for p in ref.pes]
    assert set(flat._links) == set(ref._links)
    for k, l in ref._links.items():
        assert flat._links[k].bandwidth == l.bandwidth
        assert flat._links[k].latency == l.latency
    assert flat.site_of == {FRONTEND: "edge", BACKEND: "dc"}


@pytest.mark.parametrize("policy", POLICIES)
def test_flatten_byte_identical_to_reference(policy):
    """Two-site federation vs the frozen seed engine on the flat pool."""
    merged = merge([ds_workload().instance(i) for i in range(3)])
    cost = CostModel()
    live = schedule(merged, paper_federation().flatten(), cost, policy=policy)
    ref = schedule_reference(merged, paper_pool(), cost, policy=policy)
    assert _tuples(live) == _tuples(ref)


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("seed", [0, 7])
def test_single_site_federation_byte_identical(policy, seed):
    """A one-site topology must stay byte-identical to the flat engine."""
    flat = paper_pool()
    fed = FederatedPool(
        [Site("all", flat.pes, links=tuple(flat._links.values()))])
    dag = _random_dag(seed)
    cost = CostModel()
    live = schedule(dag, fed.flatten(), cost, policy=policy)
    ref = schedule_reference(dag, flat, cost, policy=policy)
    assert _tuples(live) == _tuples(ref)


@pytest.mark.parametrize("policy", POLICIES)
def test_online_driver_accepts_federation(policy):
    """OnlineDriver(FederatedPool) drains byte-identically to the flat
    driver — the site layer adds an event surface, not a second engine."""
    wl = ds_workload()
    cost = CostModel()
    a = OnlineDriver(paper_federation(), cost, policy=policy)
    b = OnlineDriver(paper_pool(), cost, policy=policy)
    for i in range(4):
        a.submit(wl.instance(i), arrival_t=i * 3.0)
        b.submit(wl.instance(i), arrival_t=i * 3.0)
    assert _tuples(a.run()) == _tuples(b.run())
    assert a.federation is not None and b.federation is None


def test_federation_validation():
    pes = [ProcessingElement("a0", "arm", FRONTEND)]
    with pytest.raises(ValueError, match="duplicate site"):
        FederatedPool([Site("s", pes), Site("s", [])])
    with pytest.raises(ValueError, match="at least one site"):
        FederatedPool([])
    with pytest.raises(ValueError, match="unknown site"):
        FederatedPool([Site("s", pes)],
                      wan=[WANLink("s", "ghost", WAN_CLASSES["lte_4g"])])
    with pytest.raises(ValueError, match="unknown home"):
        FederatedPool([Site("s", pes)], home="ghost")
    with pytest.raises(ValueError, match="appears in sites"):
        FederatedPool([Site("s", pes),
                       Site("t", [ProcessingElement("b0", "arm", FRONTEND)])])


# ---------------------------------------------------------------------------
# Reachability / sub-topology
# ---------------------------------------------------------------------------

def _three_site():
    mk = lambda nm, kind, loc: ProcessingElement(nm, kind, loc)
    return FederatedPool(
        [Site("edge", [mk("arm0", "arm", "loc_e")]),
         Site("dc", [mk("xeon0", "xeon", "loc_d")]),
         Site("cloud", [mk("xeon1", "xeon", "loc_c")])],
        wan=[WANLink("edge", "dc", WAN_CLASSES["lte_4g"]),
             WANLink("dc", "cloud", WAN_CLASSES["metro_fiber"])],
        home="edge")


def test_reachable_bfs():
    fed = _three_site()
    assert fed.reachable() == {"edge", "dc", "cloud"}
    assert fed.reachable(cut={frozenset(("edge", "dc"))}) == {"edge"}
    assert fed.reachable(cut={frozenset(("dc", "cloud"))}) == {"edge", "dc"}
    assert fed.reachable(down={"dc"}) == {"edge"}
    assert fed.reachable(down={"edge"}) == set()


def test_sub_pool_keeps_only_internal_wan():
    fed = _three_site()
    sub = fed.sub_pool(["edge", "dc"])
    assert {p.name for p in sub.pes} == {"arm0", "xeon0"}
    assert set(sub._links) == {("loc_e", "loc_d"), ("loc_d", "loc_e")}
    assert sub.site_of == {"loc_e": "edge", "loc_d": "dc"}


def test_wan_keys_touching():
    fed = _three_site()
    assert set(fed.wan_keys_touching("dc")) == {
        ("loc_e", "loc_d"), ("loc_d", "loc_e"),
        ("loc_d", "loc_c"), ("loc_c", "loc_d")}
    assert fed.wan_pairs_touching("edge") == {frozenset(("edge", "dc"))}


# ---------------------------------------------------------------------------
# Data gravity
# ---------------------------------------------------------------------------

def test_data_gravity_pins_heavy_source_to_edge():
    """A source with heavy raw input schedules onto the data-home (edge)
    site once the cost model prices the WAN upload — and off it when the
    input is free to move."""
    fed = paper_federation()
    flat = fed.flatten()
    g = PipelineDAG("gravity")
    g.add_task(Task("src", "ingest", work=2.0, in_bytes=60e6,
                    out_bytes=1e4))
    g.add_task(Task("crunch", "kmeans", work=30.0))
    g.add_edge("src", "crunch")
    cost = CostModel(data_home=fed.data_home)
    s = schedule(g, flat, cost, policy="eft")
    src_pe = flat.pe(s.assignment("src").pe)
    assert src_pe.location == FRONTEND  # pinned by the 60 MB @12 Mbps upload
    traffic = wan_traffic(s.assignments, [g], flat, data_home=fed.data_home)
    assert traffic.upload_bytes == 0.0

    weightless = PipelineDAG("weightless")
    weightless.add_task(Task("src", "ingest", work=2.0, in_bytes=0.0))
    weightless.add_task(Task("crunch", "kmeans", work=30.0))
    weightless.add_edge("src", "crunch")
    s2 = schedule(weightless, flat, cost, policy="eft")
    src2 = flat.pe(s2.assignment("src").pe)
    assert src2.location == BACKEND  # nothing pins it; faster PE wins


def test_wan_traffic_tallies():
    fed = paper_federation()
    flat = fed.flatten()
    g = PipelineDAG("w")
    g.add_task(Task("a", "ingest", work=1.0, in_bytes=2e6, out_bytes=4e6))
    g.add_task(Task("b", "kmeans", work=1.0, out_bytes=5e5))
    g.add_task(Task("c", "export", work=1.0))
    g.add_edge("a", "b")
    g.add_edge("b", "c")
    asg = [Assignment("a", "ingest", "arm0", 0, 1, 0, 0),
           Assignment("b", "kmeans", "xeon0", 1, 2, 0, 0),
           Assignment("c", "export", "arm1", 2, 3, 0, 0)]
    t = wan_traffic(asg, [g], flat, data_home=fed.data_home)
    # a->b crosses edge->dc (4e6), b->c crosses back (5e5); a is at home
    assert t.bytes_moved == pytest.approx(4.5e6)
    assert t.crossings == 2
    assert t.upload_bytes == 0.0
    # move the source off-home: its 2e6 raw input uploads too
    asg[0] = Assignment("a", "ingest", "xeon1", 0, 1, 0, 0)
    t2 = wan_traffic(asg, [g], flat, data_home=fed.data_home)
    assert t2.upload_bytes == pytest.approx(2e6)
    assert t2.crossings == 2  # upload + b->c (a->b is now intra-dc)
    assert t2.bytes_moved == pytest.approx(2e6 + 5e5)


# ---------------------------------------------------------------------------
# Site-aware elastic pruning
# ---------------------------------------------------------------------------

def test_prune_pool_drops_wan_links_with_last_site_pe():
    flat = paper_federation().flatten()
    names = [p.name for p in flat.pes]
    mon = HealthMonitor(names)
    for nm in names:
        if flat.pe(nm).location == BACKEND:
            mon.mark_dead(nm)
    pruned = prune_pool(flat, mon)
    assert all(p.location == FRONTEND for p in pruned.pes)
    assert pruned._links == {}  # the dc uplink left with the site


def test_prune_pool_keeps_wan_links_while_site_alive():
    flat = paper_federation().flatten()
    mon = HealthMonitor([p.name for p in flat.pes])
    mon.mark_dead("xeon0")  # dc loses one PE, not the site
    pruned = prune_pool(flat, mon)
    assert set(pruned._links) == set(flat._links)


def test_prune_pool_flat_pool_never_drops_links():
    flat = paper_pool()  # no site_of metadata
    mon = HealthMonitor([p.name for p in flat.pes])
    for p in flat.pes:
        if p.location == BACKEND:
            mon.mark_dead(p.name)
    pruned = prune_pool(flat, mon)
    assert set(pruned._links) == set(flat._links)


# ---------------------------------------------------------------------------
# Cross-site VDC composition
# ---------------------------------------------------------------------------

def _mgr(edge=4, dc=8, **kw):
    import jax
    from repro.core.vdc import VDCManager
    d = jax.devices()[0]
    return VDCManager(sites={"edge": [d] * edge, "dc": [d] * dc}, **kw)


def test_compose_federated_carves_per_site():
    mgr = _mgr()
    fed = mgr.compose_federated(
        "job", {"edge": {"data": 2}, "dc": {"data": 2, "model": 2}})
    assert fed.n_chips == 6
    assert fed.sites == ("edge", "dc")
    assert mgr.free_chips == 6
    assert mgr.vdc("job@edge").n_chips == 2
    assert mgr.vdc("job@dc").axis_sizes == {"data": 2, "model": 2}
    assert mgr.federated("job") is fed


def test_compose_federated_per_site_reserve_is_atomic():
    from repro.core.vdc import SLO, AllocationError
    mgr = _mgr(edge=4, dc=8)
    slo = SLO(min_availability=0.5)  # reserve: 2 edge chips, 4 dc chips
    # dc part fits (8 free - 4 = 4 reserve ok) but the edge part violates
    # its own site reserve (4 free - 3 < 2) — nothing may be carved
    with pytest.raises(AllocationError, match="edge"):
        mgr.compose_federated(
            "job", {"dc": {"data": 4}, "edge": {"data": 3}}, slo=slo)
    assert mgr.free_chips == 12
    assert mgr.vdcs == []
    # spare capacity in the dc must not absorb an edge shortfall
    mgr.compose_federated("ok", {"dc": {"data": 4}, "edge": {"data": 2}},
                          slo=slo)
    assert mgr.free_chips == 6


def test_compose_federated_release_cycle():
    from repro.core.vdc import AllocationError
    mgr = _mgr(edge=2, dc=2)
    mgr.compose_federated("a", {"edge": {"data": 2}, "dc": {"data": 2}})
    with pytest.raises(AllocationError):
        mgr.compose_federated("b", {"edge": {"data": 1}})
    with pytest.raises(AllocationError, match="already exists"):
        mgr.compose("a", {"data": 1})  # name collides with the federated VDC
    mgr.release_federated("a")
    assert mgr.free_chips == 4
    # released chips keep their site tags: the same carve fits again
    fed = mgr.compose_federated("b", {"edge": {"data": 2}, "dc": {"data": 2}})
    assert fed.n_chips == 4


def test_compose_federated_needs_site_registry():
    import jax
    from repro.core.vdc import AllocationError, VDCManager
    mgr = VDCManager(devices=[jax.devices()[0]] * 4)
    with pytest.raises(AllocationError, match="site registry"):
        mgr.compose_federated("x", {"edge": {"data": 1}})
    with pytest.raises(AllocationError, match="unknown site"):
        _mgr().compose_federated("x", {"mars": {"data": 1}})
