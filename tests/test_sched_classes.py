"""Candidate-class grouping tests for the scheduling engine (PR 2).

The class-grouped offset-heap selector (`repro.core.schedulers._ClassedBest`)
folds interchangeable ready tasks — identical (cost rows, rank), frozen
``ready_at`` and transfer-plan signature — into one candidate class, and
keeps per-PE / per-link offset sub-heaps whose order never goes stale.
These tests stress exactly the collision structure that machinery exploits:

  * hypothesis differential: random DAGs drawn from a *tiny* op/work/bytes
    vocabulary (many tasks share cost rows) must schedule byte-identically
    to the frozen reference engine, for every policy;
  * instance-merge differential: replicated instances (the n-instance
    sweep) are the maximal-collision case, including past VoS's hard
    deadline where its offset form activates;
  * class-split unit test: same op signature but different ``ready_at``
    must never merge into one class (and equal signatures must).
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import dag as dag_mod
from repro.core.cost_model import CostModel
from repro.core.dag import PipelineDAG, Task
from repro.core.resources import paper_pool
from repro.core.schedulers import POLICIES, schedule
from repro.core.schedulers_reference import schedule_reference


def _assignment_tuples(sched):
    return [(a.task, a.op, a.pe, a.start, a.finish, a.comm_wait, a.energy)
            for a in sched.assignments]


def _collision_dag(seed: int, n_tasks: int, n_ops: int, edge_p: float,
                   arrival_period: float = 0.0):
    """Random DAG over a deliberately tiny vocabulary: only ``n_ops``
    distinct (op, work, out_bytes) combos, quantised work — so many tasks
    share an op signature and, frequently, exact ready times."""
    rng = np.random.default_rng(seed)
    ops = ["ingest", "sql_transform", "kmeans", "summarize", "window_agg",
           "linreg", "anomaly", "export"][:n_ops]
    vocab = [(op, float(1 + 2 * k), float((k % 3) * 1e6))
             for k, op in enumerate(ops)]
    g = PipelineDAG(f"coll{seed}")
    for i in range(n_tasks):
        op, work, out = vocab[int(rng.integers(len(vocab)))]
        g.add_task(Task(f"t{i:03d}", op, work=work, out_bytes=out,
                        in_bytes=4e6 if i % 7 == 0 else 0.0))
    for i in range(1, n_tasks):
        for j in range(i):
            if rng.random() < edge_p:
                g.add_edge(f"t{j:03d}", f"t{i:03d}")
    arrival = {}
    if arrival_period > 0:
        arrival = {t.name: arrival_period * (i % 5)
                   for i, t in enumerate(g.tasks)}
    return g, arrival


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n_ops=st.integers(min_value=1, max_value=4),
       edge_p=st.floats(min_value=0.0, max_value=0.35),
       period=st.floats(min_value=0.0, max_value=4.0))
def test_collision_heavy_differential(seed, n_ops, edge_p, period):
    """Byte-identical to the reference engine on signature-colliding DAGs,
    for every policy, with and without arrival maps."""
    dag, arrival = _collision_dag(seed, n_tasks=24, n_ops=n_ops,
                                  edge_p=edge_p, arrival_period=period)
    pool = paper_pool(n_arm=2, n_xeon=2)
    cost = CostModel()
    for policy in POLICIES:
        live = schedule(dag, pool, cost, policy=policy, arrival=arrival)
        ref = schedule_reference(dag, pool, cost, policy=policy,
                                 arrival=arrival)
        assert _assignment_tuples(live) == _assignment_tuples(ref), policy


def _chain_template(n_stages: int = 4) -> PipelineDAG:
    g = PipelineDAG("chain")
    prev = None
    for i, (op, work, out) in enumerate(
            [("ingest", 2.0, 2e6), ("sql_transform", 5.0, 1e6),
             ("kmeans", 9.0, 5e5), ("export", 1.0, 0.0)][:n_stages]):
        g.add_task(Task(op, op, work=work, out_bytes=out,
                        in_bytes=4e6 if i == 0 else 0.0))
        if prev:
            g.add_edge(prev, op)
        prev = op
    return g


@pytest.mark.parametrize("policy", POLICIES)
def test_instance_merge_differential(policy):
    """Replicated-instance merges (the paper's n-instance sweep) are the
    maximal class-collision case: every template task appears ×n with an
    identical signature. 40 instances also push finish times past VoS's
    hard deadline, exercising its flat-value offset form."""
    merged = dag_mod.merge([_chain_template().instance(i) for i in range(40)],
                           name="chainx40")
    pool = paper_pool(n_arm=2, n_xeon=2)
    cost = CostModel()
    live = schedule(merged, pool, cost, policy=policy)
    ref = schedule_reference(merged, pool, cost, policy=policy)
    assert _assignment_tuples(live) == _assignment_tuples(ref)


def _eft_selector(dag: PipelineDAG, pool, cost):
    """Build EFT's engine + class selector exactly as schedule_eft does,
    without running the loop (for class-structure introspection)."""
    from repro.core import schedulers as S
    eng = S._Engine(dag, pool, cost)
    rank = S._rank(dag, pool, cost)
    names = eng._di.names
    neg_rank = [-rank[nm] for nm in names]
    fin = eng._finish_fn()
    rows = eng._exec_row_ids

    def key(tid, pj):
        return (fin(tid, pj), neg_rank[tid], names[tid], pj)

    def sigfn(tid):
        return (rows[tid], neg_rank[tid])

    def offfn(tid, pj, base):
        return (eng._off_base(tid, pj), neg_rank[tid])

    return eng, S._ClassedBest(eng, key, sigfn, offfn)


def test_class_split_on_ready_at_never_merges():
    """Two tasks with the same op signature but different ready times must
    land in different candidate classes (their keys differ while a PE is
    idle); equal signatures and ready times must share one class."""
    g = PipelineDAG("split")
    # two parents with different works → children become ready at
    # different times; the children themselves are signature-identical
    g.add_task(Task("pa", "ingest", work=2.0, out_bytes=0.0))
    g.add_task(Task("pb", "ingest", work=11.0, out_bytes=0.0))
    for name, parent in (("ca", "pa"), ("cb", "pb"), ("cc", "pb")):
        g.add_task(Task(name, "kmeans", work=5.0, out_bytes=0.0))
        g.add_edge(parent, name)
    pool = paper_pool(n_arm=2, n_volta=0, n_xeon=0, n_v100=0, n_alveo=0)
    cost = CostModel()
    eng, sel = _eft_selector(g, pool, cost)

    sel.push_ready()                      # sources pa, pb
    eng._place_i(eng._di.id_of["pa"], 0)  # finish 2.0  → ca ready at 2.0
    eng._place_i(eng._di.id_of["pb"], 1)  # finish 11.0 → cb, cc ready at 11
    sel.push_ready()

    by_members = {}
    for cls in sel._classes:
        for _name, tid in cls.members:
            by_members[eng._di.names[tid]] = cls
    # same op signature, different ready_at: split
    assert by_members["ca"] is not by_members["cb"]
    # same op signature AND same ready_at: merged, name-ordered head
    assert by_members["cb"] is by_members["cc"]
    assert by_members["cb"].members[0][0] == "cb"
    # the split classes carry distinct frozen ready_at values in their sigs
    assert by_members["ca"].sig != by_members["cb"].sig


def test_offset_entries_survive_horizon_advance():
    """Offset sub-heap entries stay exact across pe_free advances: after
    placements move every horizon, pop_best must still return the exact
    reference-order candidate (smoke for the no-revalidation invariant)."""
    merged = dag_mod.merge([_chain_template().instance(i) for i in range(12)],
                           name="chainx12")
    pool = paper_pool(n_arm=2, n_xeon=2)
    cost = CostModel()
    live = schedule(merged, pool, cost, policy="eft")
    ref = schedule_reference(merged, pool, cost, policy="eft")
    assert _assignment_tuples(live) == _assignment_tuples(ref)
