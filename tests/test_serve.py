"""Serving stack: continuous-batching correctness + engine policies
(ServeEngine) and SLO-aware admission / shedding / preemption / restart
(ServingGateway), gateway tests sanitize-on via REPRO_SANITIZE=1."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.vos import ValueCurve
from repro.models import model as M
from repro.models.model import greedy_generate
from repro.serve.engine import (
    SERVE_POLICIES,
    EngineConfig,
    Request,
    RequestSpec,
    ServeEngine,
)
from repro.serve.gateway import GatewayConfig, ServingGateway, synth_requests

CFG = get_config("qwen3-0.6b", smoke=True)


@pytest.fixture(scope="module")
def params():
    return M.init(CFG, jax.random.PRNGKey(0))


def _requests(n, seed=0, arrival_gap=0.5):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        prompt = rng.integers(2, CFG.vocab_size, size=int(rng.integers(4, 12)))
        req = Request(
            rid=i,
            prompt=prompt.astype(np.int32),
            max_new_tokens=int(rng.integers(3, 8)),
            arrival=i * arrival_gap,
            curve=ValueCurve.step(i * arrival_gap + float(rng.uniform(40, 200))),
        )
        out.append(req)
    return out


def test_continuous_batching_matches_reference_greedy(params):
    cfg = EngineConfig(max_batch=2, max_seq=64, policy="eft")
    eng = ServeEngine(CFG, params, cfg)
    reqs = _requests(5)
    for r in reqs:
        eng.submit(r)
    done = {r.rid: r for r in eng.run()}
    assert len(done) == 5
    for r in reqs:
        toks = jnp.asarray(r.prompt)[None]
        ref = greedy_generate(CFG, params, toks, r.max_new_tokens + 1, max_seq=64)
        ref = np.asarray(ref)[0]
        got = np.asarray(done[r.rid].output)
        k = len(got)
        np.testing.assert_array_equal(ref[:k], got)


@pytest.mark.parametrize("policy", ["fcfs", "eft", "edf"])
def test_all_policies_complete_all_requests(params, policy):
    cfg = EngineConfig(max_batch=3, max_seq=64, policy=policy)
    eng = ServeEngine(CFG, params, cfg)
    for r in _requests(8, seed=policy.__hash__() % 100):
        eng.submit(r)
    done = eng.run()
    assert len(done) == 8
    for r in done:
        assert len(r.output) == r.max_new_tokens + 1
        assert r.finished_at is not None


def test_eft_admits_short_jobs_first(params):
    """The paper's EFT rule at the request level: with one slot and a long
    + short request waiting, EFT admits the short one first."""
    long_p = np.arange(2, 12, dtype=np.int32)
    short_p = np.arange(2, 6, dtype=np.int32)
    long_req = Request(rid=0, prompt=long_p, max_new_tokens=30)
    short_req = Request(rid=1, prompt=short_p, max_new_tokens=2)
    cfg_eft = EngineConfig(max_batch=1, max_seq=64, policy="eft")
    eng = ServeEngine(CFG, params, cfg_eft)
    eng.submit(long_req)
    eng.submit(short_req)
    eng.step()
    assert eng.slots[0] is not None and eng.slots[0].rid == 1
    # fcfs would pick the long one
    cfg_fcfs = EngineConfig(max_batch=1, max_seq=64, policy="fcfs")
    eng2 = ServeEngine(CFG, params, cfg_fcfs)
    eng2.submit(long_req)
    eng2.submit(short_req)
    eng2.step()
    assert eng2.slots[0].rid == 0


# -- RequestSpec / policy-registry regressions --------------------------------


def test_legacy_deadline_warns_and_maps_to_step_curve():
    with pytest.warns(DeprecationWarning, match="deadline"):
        r = Request(rid=0, prompt=8, max_new_tokens=2, deadline=7.5)
    assert r.curve == ValueCurve.step(7.5)
    assert r.hard_deadline == 7.5
    # an explicit curve wins; no curve means no deadline
    with pytest.warns(DeprecationWarning):
        r2 = Request(
            rid=1, prompt=8, max_new_tokens=2, deadline=7.5, curve=ValueCurve.step(3.0)
        )
    assert r2.hard_deadline == 3.0
    assert RequestSpec(rid=2, prompt=8, max_new_tokens=2).hard_deadline == float("inf")


def test_request_rejects_unknown_tier():
    with pytest.raises(ValueError, match="unknown tier"):
        RequestSpec(rid=0, prompt=8, max_new_tokens=2, tier="gold")


def test_unknown_policy_rejected_at_engine_construction():
    # fails before any model state is touched, so params=None is fine
    with pytest.raises(ValueError, match="unknown policy"):
        ServeEngine(CFG, None, EngineConfig(policy="lifo"))


def test_edf_key_orders_none_deadlines_last_with_rid_tiebreak():
    specs = [
        RequestSpec(rid=3, prompt=4, max_new_tokens=1),
        RequestSpec(rid=1, prompt=4, max_new_tokens=1),
        RequestSpec(rid=2, prompt=4, max_new_tokens=1, curve=ValueCurve.step(9.0)),
        RequestSpec(rid=0, prompt=4, max_new_tokens=1, curve=ValueCurve.step(5.0)),
    ]
    key = SERVE_POLICIES["edf"]
    order = [r.rid for r in sorted(specs, key=lambda r: key(None, r))]
    assert order == [0, 2, 1, 3]


def test_edf_engine_admits_dated_before_undated(params):
    eng = ServeEngine(CFG, params, EngineConfig(max_batch=1, max_seq=64, policy="edf"))
    prompt = np.arange(2, 8, dtype=np.int32)
    eng.submit(RequestSpec(rid=2, prompt=prompt, max_new_tokens=2))
    eng.submit(RequestSpec(rid=0, prompt=prompt, max_new_tokens=2))
    eng.submit(
        RequestSpec(rid=1, prompt=prompt, max_new_tokens=2, curve=ValueCurve.step(50.0))
    )
    done = eng.run()
    assert len(done) == 3
    admitted = [r.rid for r in sorted(done, key=lambda r: r.admitted_at)]
    # the dated request first, then the undated ones in rid order
    assert admitted == [1, 0, 2]


def test_engine_rejects_scheduling_only_prompts(params):
    eng = ServeEngine(CFG, params, EngineConfig(max_batch=1, max_seq=64))
    with pytest.raises(TypeError, match="real prompt tokens"):
        eng.submit(RequestSpec(rid=0, prompt=32, max_new_tokens=2))


def test_idle_clock_jump_and_empty_latency_stats(params):
    eng = ServeEngine(CFG, params, EngineConfig(max_batch=1, max_seq=64, policy="fcfs"))
    assert eng.latency_stats() == {
        "mean_latency": 0.0,
        "p95_latency": 0.0,
        "mean_wait": 0.0,
        "n": 0,
    }
    prompt = np.arange(2, 8, dtype=np.int32)
    eng.submit(RequestSpec(rid=0, prompt=prompt, max_new_tokens=2, arrival=5.0))
    eng.step()
    # idle engine with only future arrivals jumps to the next arrival
    # instead of spinning the tick budget away
    assert eng.clock == 5.0
    done = eng.run()
    assert len(done) == 1
    assert eng.latency_stats()["n"] == 1


# -- ServingGateway -----------------------------------------------------------


@pytest.fixture
def sanitized(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")


def _gw_cfg(max_batch=1, **kw):
    ecfg = EngineConfig(
        max_batch=max_batch, prefill_cost_per_tok=1e-3, decode_cost_per_tok=0.05
    )
    defaults = dict(ecfg=ecfg, window_s=1.0, shed_backlog_s=0.0, preempt=False)
    defaults.update(kw)
    return GatewayConfig(**defaults)


def _spec(rid, arrival, tier, dec=20):
    return RequestSpec(
        rid=rid, prompt=32, max_new_tokens=dec, arrival=arrival, tier=tier
    )


def test_gateway_tier_floors_order_admission(sanitized):
    """Same-instant arrivals admit in tier-value order: the floor-ordered
    gate is the tiered admission control (no gateway-side queueing)."""
    gw = ServingGateway(_gw_cfg())
    gw.offer(_spec(0, 0.0, "best_effort"))
    gw.offer(_spec(1, 0.0, "batch"))
    gw.offer(_spec(2, 0.0, "interactive"))
    gw.drain()
    prefills = [a.task for a in gw.drv.eng.assignments if a.task.startswith("prefill#")]
    assert prefills == ["prefill#2", "prefill#1", "prefill#0"]
    rep = gw.report()
    assert rep.n_completed == 3 and rep.n_shed == 0


def test_gateway_sheds_lowest_value_first(sanitized):
    """Overload at a window boundary sheds best-effort before batch and
    never interactive."""
    gw = ServingGateway(_gw_cfg(shed_backlog_s=2.0))
    for i in range(8):  # ~8.3s booked onto one slot in window 0
        gw.offer(_spec(i, 0.0, "batch"))
    for rid, tier in [
        (8, "best_effort"),
        (9, "best_effort"),
        (10, "batch"),
        (11, "batch"),
        (12, "interactive"),
    ]:
        gw.offer(_spec(rid, 1.5, tier))
    gw.drain()
    rep = gw.report()
    per = rep.per_tier
    assert rep.n_shed > 0
    assert per["interactive"]["shed"] == 0
    assert per["interactive"]["completed"] == 1
    # both pending best-effort requests go before any batch work does
    assert per["best_effort"]["shed"] == 2
    assert rep.n_completed + rep.n_shed == 13


def test_gateway_interactive_preempts_best_effort(sanitized):
    gw = ServingGateway(_gw_cfg(preempt=True, preempt_backlog_s=3.0))
    gw.offer(_spec(0, 0.0, "best_effort", dec=200))  # ~10s each on one slot
    gw.offer(_spec(1, 0.0, "best_effort", dec=200))
    gw.offer(_spec(2, 1.5, "interactive"))  # probes into the deep backlog
    gw.drain()
    assert gw.drv.n_preemptions == 1
    pre = gw.drv.preemptions[0]
    assert pre.victim is not None
    victim_rid = int(pre.victim.split("#", 1)[1])
    assert gw.specs[victim_rid].tier == "best_effort"
    rep = gw.report()
    assert rep.n_preemptions == 1
    assert rep.n_completed == 3  # displaced work resumes and finishes


def test_gateway_restart_matches_uninterrupted(sanitized):
    """Snapshot at a mid-trace window boundary, restore from the durable
    record, finish the trace: byte-identical schedule and report."""
    ecfg = EngineConfig(
        max_batch=2, prefill_cost_per_tok=2e-4, decode_cost_per_tok=0.02
    )
    gcfg = GatewayConfig(
        ecfg=ecfg,
        window_s=2.0,
        shed_backlog_s=3.0,
        preempt_backlog_s=2.0,
        max_preempt_probes_per_window=4,
    )
    specs = synth_requests(150, seed=3, mean_gap=0.3)
    full = ServingGateway(gcfg)
    rep_full = full.run(specs)
    assert rep_full.n_completed + rep_full.n_shed == len(specs)
    assert 0.0 < rep_full.goodput <= 1.0
    w = [int(s.arrival // gcfg.window_s) for s in specs]
    bounds = [i for i in range(1, len(specs)) if w[i] > w[i - 1]]
    assert bounds, "trace must span multiple arrival windows"
    k = bounds[len(bounds) // 2]
    gw1 = ServingGateway(gcfg)
    for s in specs[:k]:
        gw1.offer(s)
    snap = gw1.snapshot()
    gw2 = ServingGateway.restore(snap, gcfg=gcfg)
    for s in specs[k:]:
        gw2.offer(s)
    gw2.drain()
    rep_split = gw2.report()
    assert rep_split.digest == rep_full.digest
    a = dataclasses.asdict(rep_full)
    b = dataclasses.asdict(rep_split)
    for key in ("wall_seconds", "n_events"):  # telemetry, not the record
        a.pop(key)
        b.pop(key)
    assert a == b


def test_gateway_offer_validation(sanitized):
    gw = ServingGateway(_gw_cfg())
    gw.offer(_spec(0, 1.0, "batch"))
    with pytest.raises(ValueError, match="nondecreasing"):
        gw.offer(_spec(1, 0.5, "batch"))
    with pytest.raises(ValueError, match="duplicate"):
        gw.offer(_spec(0, 1.5, "batch"))


def test_gateway_serve_replays_plan_on_engine(params, sanitized):
    """End-to-end bridge: plan with the gateway, execute on the
    continuous-batching engine with real prompt tokens."""
    rng = np.random.default_rng(7)
    gw = ServingGateway(_gw_cfg(max_batch=2))
    tiers = ["interactive", "batch", "best_effort", "batch"]
    for i, tier in enumerate(tiers):
        prompt = rng.integers(2, CFG.vocab_size, size=6).astype(np.int32)
        gw.offer(
            RequestSpec(
                rid=i, prompt=prompt, max_new_tokens=3, arrival=0.4 * i, tier=tier
            )
        )
    gw.drain()
    rep = gw.report()
    assert rep.n_completed == 4
    eng = ServeEngine(CFG, params, EngineConfig(policy="fcfs", max_batch=2, max_seq=64))
    stats = gw.serve(eng)
    assert stats["n"] == rep.n_completed
    for r in eng.finished:
        assert len(r.output) == r.max_new_tokens + 1
    eft = EngineConfig(policy="eft", max_batch=2, max_seq=64)
    with pytest.raises(ValueError, match="fcfs"):
        gw.serve(ServeEngine(CFG, params, eft))
