"""Serving engine: continuous batching correctness + scheduling policies."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import model as M
from repro.models.model import greedy_generate
from repro.serve.engine import EngineConfig, Request, ServeEngine

CFG = get_config("qwen3-0.6b", smoke=True)


@pytest.fixture(scope="module")
def params():
    return M.init(CFG, jax.random.PRNGKey(0))


def _requests(n, seed=0, arrival_gap=0.5):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        prompt = rng.integers(2, CFG.vocab_size, size=int(rng.integers(4, 12)))
        req = Request(
            rid=i,
            prompt=prompt.astype(np.int32),
            max_new_tokens=int(rng.integers(3, 8)),
            arrival=i * arrival_gap,
            deadline=i * arrival_gap + float(rng.uniform(40, 200)),
        )
        out.append(req)
    return out


def test_continuous_batching_matches_reference_greedy(params):
    cfg = EngineConfig(max_batch=2, max_seq=64, policy="eft")
    eng = ServeEngine(CFG, params, cfg)
    reqs = _requests(5)
    for r in reqs:
        eng.submit(r)
    done = {r.rid: r for r in eng.run()}
    assert len(done) == 5
    for r in reqs:
        toks = jnp.asarray(r.prompt)[None]
        ref = greedy_generate(CFG, params, toks, r.max_new_tokens + 1, max_seq=64)
        ref = np.asarray(ref)[0]
        got = np.asarray(done[r.rid].output)
        k = len(got)
        np.testing.assert_array_equal(ref[:k], got)


@pytest.mark.parametrize("policy", ["fcfs", "eft", "edf"])
def test_all_policies_complete_all_requests(params, policy):
    cfg = EngineConfig(max_batch=3, max_seq=64, policy=policy)
    eng = ServeEngine(CFG, params, cfg)
    for r in _requests(8, seed=policy.__hash__() % 100):
        eng.submit(r)
    done = eng.run()
    assert len(done) == 8
    for r in done:
        assert len(r.output) == r.max_new_tokens + 1
        assert r.finished_at is not None


def test_eft_admits_short_jobs_first(params):
    """The paper's EFT rule at the request level: with one slot and a long
    + short request waiting, EFT admits the short one first."""
    long_p = np.arange(2, 12, dtype=np.int32)
    short_p = np.arange(2, 6, dtype=np.int32)
    long_req = Request(rid=0, prompt=long_p, max_new_tokens=30)
    short_req = Request(rid=1, prompt=short_p, max_new_tokens=2)
    cfg_eft = EngineConfig(max_batch=1, max_seq=64, policy="eft")
    eng = ServeEngine(CFG, params, cfg_eft)
    eng.submit(long_req)
    eng.submit(short_req)
    eng.step()
    assert eng.slots[0] is not None and eng.slots[0].rid == 1
    # fcfs would pick the long one
    cfg_fcfs = EngineConfig(max_batch=1, max_seq=64, policy="fcfs")
    eng2 = ServeEngine(CFG, params, cfg_fcfs)
    eng2.submit(long_req)
    eng2.submit(short_req)
    eng2.step()
    assert eng2.slots[0].rid == 0
