"""Chaos harness: randomized mid-flight failures vs the recovery invariants.

A hypothesis scenario fuzzer over (DAG template x step count x failure
time x failed-PE set x arrival period x policy). Every scenario must
satisfy, after ``OnlineDriver.fail`` and a full drain:

  * **recovery differential** — continuing the failed driver is
    byte-identical to ``restart_from_history`` on the surviving pool with
    the surviving record + retry floors + cancellations;
  * **no lost tasks** — every admitted, non-cancelled task is placed
    exactly once in the final schedule;
  * **no zombie placements** — nothing placed on a dead PE after the
    failure time, and every resubmitted task starts at/after its retry
    floor (>= the failure time);
  * **dependency soundness** — nothing executes (``start + comm_wait``)
    before all its predecessors' recorded finishes, across the
    survivor/recompute boundary.

Strategies stick to integers/floats/sampled_from so the module runs
under the deterministic conftest fallback when hypothesis is not
installed.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.cost_model import CostModel
from repro.core.dag import PipelineDAG, Task
from repro.core.federation import paper_federation
from repro.core.online import OnlineDriver, restart_from_history
from repro.core.resources import paper_pool
from repro.core.schedulers import POLICIES

N_INSTANCES = 5
OPS = [
    "ingest",
    "sql_transform",
    "kmeans",
    "summarize",
    "window_agg",
    "linreg",
    "anomaly",
    "export",
]


def _template(seed: int, n: int = 8) -> PipelineDAG:
    rng = np.random.default_rng(seed)
    g = PipelineDAG(f"chaos{seed}")
    for i in range(n):
        task = Task(
            f"t{i}",
            str(rng.choice(OPS)),
            work=float(rng.uniform(0.5, 12)),
            out_bytes=float(rng.uniform(0, 3e6)),
            in_bytes=float(rng.uniform(0, 6e6)) if i == 0 else 0,
        )
        g.add_task(task)
    for i in range(1, n):
        for j in rng.choice(i, size=min(i, 2), replace=False):
            g.add_edge(f"t{j}", f"t{i}")
    return g


def _tuples(sched):
    return [
        (a.task, a.op, a.pe, a.start, a.finish, a.comm_wait, a.energy)
        for a in sched.assignments
    ]


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    k=st.integers(min_value=1, max_value=30),
    n_dead=st.integers(min_value=1, max_value=2),
    dead_at=st.integers(min_value=0, max_value=10_000),
    frac=st.floats(min_value=0.0, max_value=1.0),
    period=st.floats(min_value=0.0, max_value=10.0),
    policy=st.sampled_from(POLICIES),
)
def test_chaos_recovery_invariants(seed, k, n_dead, dead_at, frac, period, policy):
    wl = _template(seed)
    pool = paper_pool(n_arm=2, n_xeon=2)
    cost = CostModel()
    drv = OnlineDriver(pool, cost, policy=policy)
    for i in range(N_INSTANCES):
        drv.submit(wl.instance(i), arrival_t=i * period)
    for _ in range(k):
        if drv.step() is None and not drv.pending:
            break
    if not drv.eng.assignments:
        return  # nothing in flight; nothing to chaos
    # failure time somewhere inside the placed record's span
    starts = sorted(a.start for a in drv.eng.assignments)
    t_fail = starts[int(frac * (len(starts) - 1))]
    pes = [p.name for p in pool.pes]
    rng = np.random.default_rng(dead_at)
    dead = list(rng.choice(pes, size=n_dead, replace=False))
    rep = drv.fail(t_fail, dead)

    # durable record snapshot, then drain both paths
    history = list(drv.eng.assignments)
    admitted = [(inst.dag, inst.arrival) for inst in drv.instances]
    pending = drv.pending_submissions()
    loc_of = dict(drv._loc_of)
    floors = dict(drv.retry_floors)
    cancelled = list(drv.cancelled_instances)
    sched_a = drv.run()
    drv_b = restart_from_history(
        drv.pool,
        cost,
        policy,
        admitted,
        history,
        pending,
        loc_of,
        retry_floors=floors,
        cancelled=cancelled,
    )
    sched_b = drv_b.run()

    # 1) recovery differential
    assert _tuples(sched_a) == _tuples(sched_b)

    # 2) no lost tasks: every non-cancelled task placed exactly once
    cancelled_set = set(cancelled)
    expected = {
        t.name
        for inst in drv.instances
        if inst.name not in cancelled_set
        for t in inst.dag.tasks
    }
    expected |= {
        t.name
        for dag, _t in pending
        if dag.name not in cancelled_set
        for t in dag.tasks
    }
    placed_names = [a.task for a in sched_a.assignments]
    assert sorted(placed_names) == sorted(expected)

    # 3) no zombie placements + retry floors respected
    by_task = {a.task: a for a in sched_a.assignments}
    for a in sched_a.assignments:
        if a.start >= t_fail:
            assert a.pe not in dead, f"{a.task} on dead {a.pe} at {a.start}"
    for nm in rep.lost:
        if nm in by_task:  # not cancelled with its instance
            assert by_task[nm].start >= rep.retry_floors.get(nm, t_fail)

    # 4) dependency soundness across the survivor/recompute boundary:
    # nothing executes (start + comm_wait) before its inputs exist
    for inst in drv.instances:
        if inst.name in cancelled_set:
            continue
        for t_ in inst.dag.tasks:
            a = by_task[t_.name]
            for p in inst.dag.predecessors(t_.name):
                pf = by_task[p.name].finish
                assert a.start + a.comm_wait >= pf - 1e-9, f"{t_.name} < {p.name}"


@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    k1=st.integers(min_value=1, max_value=20),
    k2=st.integers(min_value=1, max_value=15),
    policy=st.sampled_from(["eft", "etf", "heft", "vos"]),
)
def test_chaos_double_failure_differential(seed, k1, k2, policy):
    """Two failures back-to-back (cumulative floors, shrinking pool): the
    durable record after the *second* failure still restarts
    byte-identically — including orphan survivors whose producer is being
    recomputed for a third consumer."""
    wl = _template(seed)
    pool = paper_pool(n_arm=2, n_xeon=2)
    cost = CostModel()
    rng = np.random.default_rng(seed)
    drv = OnlineDriver(pool, cost, policy=policy)
    for i in range(N_INSTANCES):
        drv.submit(wl.instance(i), arrival_t=i * 2.0)
    for _ in range(k1):
        if drv.step() is None and not drv.pending:
            break
    if not drv.eng.assignments:
        return
    pes = [p.name for p in drv.pool.pes]
    drv.fail(max(a.start for a in drv.eng.assignments), [str(rng.choice(pes))])
    for _ in range(k2):
        if drv.step() is None and not drv.pending:
            break
    if len(drv.pool.pes) > 2 and drv.eng.assignments:
        pes = [p.name for p in drv.pool.pes]
        drv.fail(max(a.start for a in drv.eng.assignments), [str(rng.choice(pes))])
    history = list(drv.eng.assignments)
    admitted = [(inst.dag, inst.arrival) for inst in drv.instances]
    pending = drv.pending_submissions()
    sa = _tuples(drv.run())
    drv_b = restart_from_history(
        drv.pool,
        cost,
        policy,
        admitted,
        history,
        pending,
        dict(drv._loc_of),
        retry_floors=dict(drv.retry_floors),
        cancelled=list(drv.cancelled_instances),
    )
    assert sa == _tuples(drv_b.run())


def _site_fuzz(seed, policy, n_ops):
    """Drive a two-site federation through a random legal sequence of
    site-granularity events (partition / heal / fail_site / rejoin_site),
    interleaved with placement steps. Returns the driver, the cost model,
    and whether the last event rebound the policy run (rr's differential
    is only pinned at rebind points — its PE cycle is positional)."""
    fed = paper_federation(n_arm=2, n_xeon=2)
    cost = CostModel(data_home=fed.data_home)
    drv = OnlineDriver(fed, cost, policy=policy)
    wl = _template(seed)
    for i in range(N_INSTANCES):
        drv.submit(wl.instance(i), arrival_t=i * 3.0)
    rng = np.random.default_rng(seed + 99)
    t = 0.0
    rebound = True
    for _ in range(n_ops):
        for _ in range(int(rng.integers(0, 7))):
            if drv.step() is None and not drv.pending:
                break
        if drv.eng.assignments:
            t = max(t, max(a.start for a in drv.eng.assignments))
        t += float(rng.uniform(0.1, 40.0))
        down = "dc" in drv._down_sites
        cut = "dc" in drv._partition_saved
        if down:
            t += float(rng.uniform(0.0, 90.0))
            acc, _refused = drv.rejoin_site(t, "dc")
            rebound = bool(acc)
        elif cut:
            if rng.random() < 0.7:
                t += float(rng.uniform(0.0, 80.0))  # within or past window
                n_ev = len(drv.horizon_events)
                rep = drv.heal(t, "dc")
                rebound = rep is not None or len(drv.horizon_events) > n_ev
            else:
                drv.fail_site(t, "dc")  # the dark site was actually dead
                rebound = True
        else:
            if rng.random() < 0.6:
                drv.partition(t, "dc",
                              defer="all" if rng.random() < 0.5 else (),
                              shed="auto" if rng.random() < 0.3 else 0)
            else:
                drv.fail_site(t, "dc", shed=int(rng.integers(0, 2)))
            rebound = True
    return drv, cost, rebound


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    n_ops=st.integers(min_value=1, max_value=4),
    policy=st.sampled_from(POLICIES),
)
def test_chaos_site_events_differential(seed, n_ops, policy):
    """Any site-loss / partition / heal sequence: the drain stays
    byte-identical to ``restart_from_history`` on the reachable
    sub-topology with the durable record + horizon-event log, and every
    surviving task is placed exactly once."""
    drv, cost, rebound = _site_fuzz(seed, policy, n_ops)

    history = list(drv.eng.assignments)
    admitted = [(inst.dag, inst.arrival) for inst in drv.instances]
    pending = drv.pending_submissions()
    loc_of = dict(drv._loc_of)
    floors = dict(drv.retry_floors)
    cancelled = list(drv.cancelled_instances)
    events = list(drv.horizon_events)
    sched_a = drv.run()

    # exactly-once: no duplicates, every surviving (non-cancelled,
    # non-shed) task placed, nothing placed that was never submitted
    names = [a.task for a in sched_a.assignments]
    assert len(names) == len(set(names))
    cancelled_set = set(cancelled)
    must_place = {
        t.name
        for inst in drv.instances
        if inst.name not in cancelled_set
        for t in inst.dag.tasks
    }
    must_place |= {
        t.name
        for dag, _t in pending
        if dag.name not in cancelled_set
        for t in dag.tasks
    }
    all_submitted = {
        t.name for inst in drv.instances for t in inst.dag.tasks
    } | {t.name for dag, _t in pending for t in dag.tasks}
    assert must_place <= set(names) <= all_submitted

    if policy == "rr" and not rebound:
        return  # rr's positional cycle: differential pinned at rebinds only
    drv_b = restart_from_history(
        drv.pool,
        cost,
        policy,
        admitted,
        history,
        pending,
        loc_of,
        retry_floors=floors,
        cancelled=cancelled,
        horizon_events=events,
    )
    assert _tuples(sched_a) == _tuples(drv_b.run())


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    n_ops=st.integers(min_value=1, max_value=4),
)
def test_chaos_preemption_vs_partition_differential(seed, n_ops):
    """Value-aware preempting admissions racing site partitions/heals:
    whatever interleaving the fuzzer picks, the drain stays byte-identical
    to ``restart_from_history`` on the durable record (floors + horizon
    events + curves), every surviving task is placed exactly once, and
    displaced victims restart at/after their priced resume floors."""
    from repro.core.vos import ValueCurve

    fed = paper_federation(n_arm=2, n_xeon=2)
    cost = CostModel(data_home=fed.data_home)
    drv = OnlineDriver(fed, cost, policy="vos")
    wl = _template(seed)
    cold = ValueCurve.linear_decay(4e4, 9e4, value=0.2)
    for i in range(N_INSTANCES):
        drv.submit(wl.instance(i), arrival_t=i * 3.0, curve=cold)
    rng = np.random.default_rng(seed + 7)
    t = 0.0
    idx = N_INSTANCES
    reports = []
    for _ in range(n_ops):
        for _ in range(int(rng.integers(1, 8))):
            if drv.step() is None and not drv.pending:
                break
        if drv.eng.assignments:
            t = max(t, max(a.start for a in drv.eng.assignments))
        t += float(rng.uniform(0.1, 30.0))
        cut = "dc" in drv._partition_saved
        r = rng.random()
        if cut and r < 0.5:
            t += float(rng.uniform(0.0, 80.0))  # within or past the window
            drv.heal(t, "dc")
        elif not cut and r < 0.4:
            drv.partition(t, "dc")
        else:
            hot = ValueCurve.linear_decay(t + 5e4, t + 9e4, value=50.0)
            reports.append(drv.admit_preempting(wl.instance(idx), t,
                                                curve=hot))
            idx += 1

    history = list(drv.eng.assignments)
    admitted = [(inst.dag, inst.arrival) for inst in drv.instances]
    pending = drv.pending_submissions()
    loc_of = dict(drv._loc_of)
    floors = dict(drv.retry_floors)
    cancelled = list(drv.cancelled_instances)
    events = list(drv.horizon_events)
    curves = drv.slo_curves()
    sched_a = drv.run()

    names = [a.task for a in sched_a.assignments]
    assert len(names) == len(set(names))
    must_place = {
        t_.name for inst in drv.instances for t_ in inst.dag.tasks
    } | {t_.name for dag, _t in pending for t_ in dag.tasks}
    assert sorted(names) == sorted(must_place)
    by_task = {a.task: a for a in sched_a.assignments}
    for rep in reports:
        if rep.victim is not None:
            assert by_task[rep.victim].start >= rep.resume_floor - 1e-9
    assert drv.n_preemptions == sum(1 for r in reports
                                    if r.victim is not None)

    drv_b = restart_from_history(
        drv.pool,
        cost,
        "vos",
        admitted,
        history,
        pending,
        loc_of,
        retry_floors=floors,
        cancelled=cancelled,
        horizon_events=events,
        curves=curves,
    )
    assert _tuples(sched_a) == _tuples(drv_b.run())
