#!/usr/bin/env python
"""detlint — repo-specific determinism lint for the byte-identical engine.

Every correctness pin in this repo is a byte-identical-schedule claim
(golden digests, differential fuzzers). Those pins catch nondeterminism
*after* it produced a divergent schedule; this lint catches the classic
sources at parse time:

DET101  iteration over an unordered (or order-fragile) collection —
        ``.items()`` / ``.keys()`` / ``.values()`` / ``set`` literals and
        constructors — without a ``sorted()`` wrapper.  Python dicts are
        insertion-ordered, but insertion order is itself a determinism
        obligation nobody checks; every such loop must either sort or
        carry an annotation arguing why its order is deterministic.
        Scope: ``src/`` (library + engine code).
DET102  unseeded or process-global RNG use (``random.random()``,
        ``np.random.rand()``, ``default_rng()`` with no seed, …).
        Scope: everywhere.
DET103  wall-clock reads (``time.time``, ``datetime.now``) in engine
        code — simulated time must never couple to real time.
        Scope: ``src/repro/core/``.
DET104  float accumulation (``sum``) over an unordered collection —
        float addition is non-associative, so the order of the operands
        changes the result bit pattern.  (``math.fsum`` is exempt: it is
        exactly rounded, hence order-independent.)  Scope: ``src/``.
DET105  direct writes to monotone horizon state (``pe_free`` /
        ``link_free``) outside the designated mutator helpers.  The
        engine's incremental selectors assume horizons only move through
        those helpers (which bump the dirty epochs); a stray write
        silently desynchronises the candidate heaps.  Scope: everywhere.

Suppression: append ``# det: ok <reason>`` to the flagged line (the
``for``/assignment line or any line of the offending expression).  The
reason is mandatory — a bare ``# det: ok`` is itself a finding.

Usage::

    python tools/detlint.py src tests benchmarks
    python tools/detlint.py --stats src

Exit status 1 if any unannotated finding remains, 2 on usage errors.
"""

from __future__ import annotations

import argparse
import ast
import sys
from dataclasses import dataclass
from pathlib import Path

# Functions allowed to write pe_free/link_free: the engine's designated
# horizon mutators (schedulers.py) — they pair every write with the dirty
# epoch bump the incremental selectors rely on.  __init__ is allowed so
# engines/tests can build the state in the first place.
HORIZON_MUTATORS = frozenset(
    {
        "__init__",
        "_place_i",
        "_exec_start_book_i",
        "apply_horizon_event",
        "repool",
        "invalidate",
        "_replay_trusted",
        "_replay_ghost",
    }
)

HORIZON_ATTRS = frozenset({"pe_free", "_pe_free", "link_free"})

# Mutating dict/list method calls that count as writes for DET105.
MUTATING_METHODS = frozenset(
    {"clear", "pop", "popitem", "update", "setdefault", "append", "extend"}
)

UNORDERED_VIEW_METHODS = frozenset({"items", "keys", "values"})

# Wrappers that preserve whatever order their argument has: seeing one of
# these around sorted() is fine, seeing one around .items() is not.
ORDER_PRESERVING_WRAPPERS = frozenset(
    {"enumerate", "reversed", "list", "tuple", "iter"}
)

# Module-level RNG functions on the stdlib `random` module that draw from
# the process-global generator.
GLOBAL_RANDOM_FNS = frozenset(
    {
        "random",
        "randint",
        "randrange",
        "uniform",
        "choice",
        "choices",
        "shuffle",
        "sample",
        "gauss",
        "normalvariate",
        "betavariate",
        "expovariate",
        "getrandbits",
        "triangular",
    }
)

# Legacy numpy global-state RNG entry points (np.random.<fn>).
GLOBAL_NP_RANDOM_FNS = frozenset(
    {
        "rand",
        "randn",
        "randint",
        "random",
        "random_sample",
        "choice",
        "shuffle",
        "permutation",
        "uniform",
        "normal",
        "seed",
    }
)

WALL_CLOCK_TIME_FNS = frozenset({"time", "time_ns"})
WALL_CLOCK_DATETIME_FNS = frozenset({"now", "utcnow", "today"})

PRAGMA = "# det: ok"


@dataclass(frozen=True)
class Finding:
    path: Path
    line: int
    col: int
    code: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col + 1}: {self.code} {self.message}"


def _attr_chain_tail(node: ast.expr) -> str | None:
    """Name of the final attribute/name in a dotted chain, else None."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _unwrap_order_preserving(node: ast.expr) -> ast.expr:
    """Strip enumerate()/reversed()/list()/tuple()/iter() wrappers."""
    while (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Name)
        and node.func.id in ORDER_PRESERVING_WRAPPERS
        and node.args
    ):
        node = node.args[0]
    return node


def _is_sorted_call(node: ast.expr) -> bool:
    return (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Name)
        and node.func.id in {"sorted", "min", "max"}
    )


def _unordered_source(node: ast.expr) -> str | None:
    """Describe ``node`` if it is an unordered-iteration source."""
    if isinstance(node, ast.Call):
        fn = node.func
        if (
            isinstance(fn, ast.Attribute)
            and fn.attr in UNORDERED_VIEW_METHODS
            and not node.args
            and not node.keywords
        ):
            return f".{fn.attr}()"
        if isinstance(fn, ast.Name) and fn.id in {"set", "frozenset"}:
            return f"{fn.id}(...)"
    if isinstance(node, ast.Set):
        return "set literal"
    if isinstance(node, ast.SetComp):
        return "set comprehension"
    return None


def _iter_violation(node: ast.expr) -> str | None:
    """Check a for/comprehension iterable for an unordered source."""
    node = _unwrap_order_preserving(node)
    if _is_sorted_call(node):
        return None
    return _unordered_source(node)


class _FileChecker(ast.NodeVisitor):
    def __init__(
        self,
        path: Path,
        source: str,
        *,
        in_src: bool,
        in_engine: bool,
    ) -> None:
        self.path = path
        self.lines = source.splitlines()
        self.in_src = in_src
        self.in_engine = in_engine
        self.findings: list[Finding] = []
        self.annotated = 0
        self.bad_pragmas: list[int] = []
        self._func_stack: list[str] = []
        self._pragma_lines = self._collect_pragmas()

    # -- pragma handling ---------------------------------------------------

    def _collect_pragmas(self) -> set[int]:
        ok: set[int] = set()
        for i, line in enumerate(self.lines, start=1):
            idx = line.find(PRAGMA)
            if idx < 0:
                continue
            reason = line[idx + len(PRAGMA) :].strip()
            if reason:
                ok.add(i)
            else:
                self.bad_pragmas.append(i)
        return ok

    def _suppressed(self, node: ast.AST) -> bool:
        first = getattr(node, "lineno", None)
        last = getattr(node, "end_lineno", None) or first
        if first is None:
            return False
        return any(ln in self._pragma_lines for ln in range(first, last + 1))

    def _emit(self, node: ast.AST, code: str, message: str) -> None:
        if self._suppressed(node):
            self.annotated += 1
            return
        self.findings.append(
            Finding(
                self.path,
                getattr(node, "lineno", 0),
                getattr(node, "col_offset", 0),
                code,
                message,
            )
        )

    # -- scope bookkeeping -------------------------------------------------

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._func_stack.append(node.name)
        self.generic_visit(node)
        self._func_stack.pop()

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._func_stack.append(node.name)
        self.generic_visit(node)
        self._func_stack.pop()

    # -- DET101: unordered iteration --------------------------------------

    def _check_iter(self, iter_node: ast.expr, site: ast.AST) -> None:
        if not self.in_src:
            return
        desc = _iter_violation(iter_node)
        if desc:
            self._emit(
                site,
                "DET101",
                f"iteration over unordered {desc} without sorted() — "
                "sort it or annotate '# det: ok <why deterministic>'",
            )

    def visit_For(self, node: ast.For) -> None:
        self._check_iter(node.iter, node)
        self.generic_visit(node)

    def visit_AsyncFor(self, node: ast.AsyncFor) -> None:
        self._check_iter(node.iter, node)
        self.generic_visit(node)

    def _visit_comp(self, node: ast.AST) -> None:
        for gen in node.generators:
            self._check_iter(gen.iter, gen.iter)
        self.generic_visit(node)

    visit_ListComp = _visit_comp
    visit_SetComp = _visit_comp
    visit_DictComp = _visit_comp
    visit_GeneratorExp = _visit_comp

    # -- call-based rules ---------------------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        self._check_rng(node)
        self._check_wall_clock(node)
        self._check_float_sum(node)
        self._check_horizon_method_call(node)
        self.generic_visit(node)

    def _check_rng(self, node: ast.Call) -> None:
        fn = node.func
        if not isinstance(fn, ast.Attribute):
            # bare Random() with no seed
            if (
                isinstance(fn, ast.Name)
                and fn.id == "Random"
                and not node.args
            ):
                self._emit(node, "DET102", "Random() constructed without a seed")
            return
        owner = fn.value
        # random.<fn>(...) on the stdlib module (global generator)
        if (
            isinstance(owner, ast.Name)
            and owner.id == "random"
            and fn.attr in GLOBAL_RANDOM_FNS
        ):
            self._emit(
                node,
                "DET102",
                f"process-global RNG random.{fn.attr}() — "
                "use a seeded random.Random(seed) instance",
            )
            return
        if fn.attr == "Random" and not node.args:
            self._emit(node, "DET102", "random.Random() without a seed")
            return
        # np.random.<fn>(...) legacy global state
        if (
            isinstance(owner, ast.Attribute)
            and owner.attr == "random"
            and isinstance(owner.value, ast.Name)
            and owner.value.id in {"np", "numpy"}
        ):
            if fn.attr in GLOBAL_NP_RANDOM_FNS:
                self._emit(
                    node,
                    "DET102",
                    f"numpy global RNG np.random.{fn.attr}() — "
                    "use np.random.default_rng(seed)",
                )
            elif fn.attr == "default_rng" and not node.args and not node.keywords:
                self._emit(
                    node,
                    "DET102",
                    "np.random.default_rng() without a seed draws OS entropy",
                )
            return
        if (
            fn.attr == "default_rng"
            and not node.args
            and not node.keywords
            and isinstance(owner, ast.Name)
            and owner.id == "random"
        ):
            self._emit(
                node,
                "DET102",
                "default_rng() without a seed draws OS entropy",
            )

    def _check_wall_clock(self, node: ast.Call) -> None:
        if not self.in_engine:
            return
        fn = node.func
        if not isinstance(fn, ast.Attribute):
            return
        owner = fn.value
        if (
            isinstance(owner, ast.Name)
            and owner.id == "time"
            and fn.attr in WALL_CLOCK_TIME_FNS
        ):
            self._emit(
                node,
                "DET103",
                f"wall-clock time.{fn.attr}() in engine code — "
                "simulated time must not couple to real time",
            )
        elif fn.attr in WALL_CLOCK_DATETIME_FNS and _attr_chain_tail(owner) in {
            "datetime",
            "date",
        }:
            self._emit(
                node,
                "DET103",
                f"wall-clock datetime {fn.attr}() in engine code",
            )

    def _check_float_sum(self, node: ast.Call) -> None:
        if not self.in_src:
            return
        fn = node.func
        if not (isinstance(fn, ast.Name) and fn.id == "sum" and node.args):
            return
        arg = _unwrap_order_preserving(node.args[0])
        if _is_sorted_call(arg):
            return
        desc = _unordered_source(arg)
        if desc is None and isinstance(arg, ast.GeneratorExp):
            for gen in arg.generators:
                desc = _iter_violation(gen.iter)
                if desc:
                    break
        if desc:
            self._emit(
                node,
                "DET104",
                f"float sum() over unordered {desc} — float addition is "
                "order-sensitive; sort the operands or use math.fsum",
            )

    # -- DET105: horizon writes ---------------------------------------------

    def _horizon_target_name(self, node: ast.expr) -> str | None:
        """Return the horizon attr if ``node`` stores into pe_free/link_free.

        A plain ``pe_free = ...`` name binding is NOT a write — it is the
        repo idiom for hoisting a read alias out of a hot loop — but
        ``x.pe_free = ...``, ``pe_free[j] = ...`` and ``x.pe_free[j] = ...``
        all mutate the shared horizon state.
        """
        if isinstance(node, ast.Subscript):
            node = node.value
        elif isinstance(node, ast.Name):
            return None
        tail = _attr_chain_tail(node)
        if tail in HORIZON_ATTRS:
            return tail
        return None

    def _in_designated_mutator(self) -> bool:
        return any(f in HORIZON_MUTATORS for f in self._func_stack)

    def _emit_horizon(self, node: ast.AST, attr: str, verb: str) -> None:
        self._emit(
            node,
            "DET105",
            f"{verb} to monotone horizon state '{attr}' outside the "
            "designated mutators "
            "(_place_i/apply_horizon_event/repool/invalidate/replay)",
        )

    def visit_Assign(self, node: ast.Assign) -> None:
        if not self._in_designated_mutator():
            flat: list[ast.expr] = []
            for tgt in node.targets:
                if isinstance(tgt, (ast.Tuple, ast.List)):
                    flat.extend(tgt.elts)
                else:
                    flat.append(tgt)
            for tgt in flat:
                attr = self._horizon_target_name(tgt)
                if attr:
                    self._emit_horizon(node, attr, "direct write")
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        if not self._in_designated_mutator():
            attr = self._horizon_target_name(node.target)
            if attr:
                self._emit_horizon(node, attr, "augmented write")
        self.generic_visit(node)

    def visit_Delete(self, node: ast.Delete) -> None:
        if not self._in_designated_mutator():
            for tgt in node.targets:
                attr = self._horizon_target_name(tgt)
                if attr:
                    self._emit_horizon(node, attr, "delete")
        self.generic_visit(node)

    def _check_horizon_method_call(self, node: ast.Call) -> None:
        fn = node.func
        if not (isinstance(fn, ast.Attribute) and fn.attr in MUTATING_METHODS):
            return
        # .get()/.items() reads are fine; only mutating methods get here
        if fn.attr == "pop" and not node.args:
            pass  # list.pop() with no args still mutates — keep flagging
        tail = _attr_chain_tail(fn.value)
        if tail in HORIZON_ATTRS and not self._in_designated_mutator():
            self._emit_horizon(node, tail, f".{fn.attr}() call")


def check_file(path: Path, *, repo_root: Path | None = None) -> _FileChecker:
    rel = path
    if repo_root is not None:
        try:
            rel = path.resolve().relative_to(repo_root.resolve())
        except ValueError:
            rel = path
    posix = rel.as_posix()
    in_src = posix.startswith("src/") or "/src/" in posix
    in_engine = "src/repro/core/" in posix or posix.startswith("src/repro/core")
    source = path.read_text(encoding="utf-8")
    checker = _FileChecker(path, source, in_src=in_src, in_engine=in_engine)
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as exc:
        checker.findings.append(
            Finding(path, exc.lineno or 0, 0, "DET000", f"syntax error: {exc.msg}")
        )
        return checker
    checker.visit(tree)
    for ln in checker.bad_pragmas:
        checker.findings.append(
            Finding(
                path,
                ln,
                0,
                "DET100",
                "bare '# det: ok' pragma — a justification is mandatory",
            )
        )
    return checker


def iter_python_files(roots: list[str]) -> list[Path]:
    files: list[Path] = []
    for root in roots:
        p = Path(root)
        if p.is_file() and p.suffix == ".py":
            files.append(p)
        elif p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        else:
            print(f"detlint: no such path: {root}", file=sys.stderr)
            raise SystemExit(2)
    return files


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="detlint", description="determinism lint (see module docstring)"
    )
    ap.add_argument("paths", nargs="+", help="files or directories to scan")
    ap.add_argument(
        "--stats",
        action="store_true",
        help="print per-rule finding/annotation counts",
    )
    args = ap.parse_args(argv)

    repo_root = Path(__file__).resolve().parent.parent
    findings: list[Finding] = []
    annotated = 0
    nfiles = 0
    for path in iter_python_files(args.paths):
        checker = check_file(path, repo_root=repo_root)
        findings.extend(checker.findings)
        annotated += checker.annotated
        nfiles += 1

    for f in findings:
        print(f.render())
    if args.stats:
        by_code: dict[str, int] = {}
        for f in findings:
            by_code[f.code] = by_code.get(f.code, 0) + 1
        for code in sorted(by_code):
            print(f"{code}: {by_code[code]} unannotated")
        print(f"{annotated} annotated suppression(s) across {nfiles} file(s)")
    if findings:
        print(
            f"detlint: {len(findings)} unannotated finding(s) "
            f"({annotated} annotated) in {nfiles} file(s)",
            file=sys.stderr,
        )
        return 1
    print(f"detlint: clean — {nfiles} file(s), {annotated} annotated suppression(s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
