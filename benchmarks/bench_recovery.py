"""Failure-recovery benchmark: fail() latency vs a cold re-plan + MTBF sweep.

    PYTHONPATH=src python benchmarks/bench_recovery.py \
        [--n 1000] [--policies eft,vos] [--period 5.0] \
        [--mtbfs 50,200,800] [--sweep-n 60] [--out BENCH_sched.json] \
        [--max-ratio 2.0] [--smoke]

Two experiments on ``ds_workload`` instances streaming onto ``paper_pool``:

  * **recovery latency** (gated) — step two identical drivers ~25% of the
    way through n instances, then kill two PEs mid-flight on one
    (``OnlineDriver.fail``: lineage + invalidation + trusted replay +
    resubmission) and merely shrink the pool on the other
    (``OnlineDriver.repool`` — the cold elastic re-plan that keeps all
    placed work). The report's ``wall_seconds`` must stay within
    ``--max-ratio`` (default 2.0) of the cold re-plan: recovering lost
    work may not cost materially more than the re-plan it subsumes.
  * **MTBF sweep** (reported) — drive n instances to completion while
    killing a rotating PE every ``mtbf`` sim-seconds (the previously
    killed PE rejoins when its flap quarantine allows). Reported per
    mtbf: failures survived, goodput (useful exec-seconds over useful +
    invalidated), lost-work ratio, mean recovery latency and final
    makespan — the graceful-degradation trajectory as failures get
    denser.

With ``--out`` pointing at BENCH_sched.json the results are merged into
that file under a ``"recovery"`` key (other sections stay untouched).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

DEAD = ("xeon2", "arm1")
ROTATION = ("xeon2", "arm1", "xeon1")


def _mk_driver(wl, pool, cost, policy, n, period):
    from repro.core.online import OnlineDriver

    drv = OnlineDriver(pool, cost, policy=policy)
    for i in range(n):
        drv.submit(wl.instance(i), arrival_t=i * period)
    return drv


def bench_latency(n, policies, period, max_ratio):
    from repro.core.cost_model import CostModel
    from repro.core.resources import paper_pool
    from repro.pipeline.workloads import ds_workload

    wl = ds_workload()
    cost = CostModel()
    steps = max(len(wl.tasks) * n // 4, 8)
    results: dict = {}
    failures: list = []
    for pol in policies:
        drv_a = _mk_driver(wl, paper_pool(), cost, pol, n, period)
        drv_b = _mk_driver(wl, paper_pool(), cost, pol, n, period)
        for _ in range(steps):
            drv_a.step()
            drv_b.step()
        t_fail = max(a.start for a in drv_a.eng.assignments)
        rep = drv_a.fail(t_fail, list(DEAD))
        fail_s = rep.wall_seconds
        t0 = time.perf_counter()
        drv_b.repool(drv_b.pool.without(list(DEAD)))
        repool_s = time.perf_counter() - t0
        ratio = fail_s / repool_s if repool_s > 0 else float("inf")
        results[pol] = {
            "n": n,
            "placed_at_failure": steps,
            "fail_seconds": round(fail_s, 4),
            "repool_seconds": round(repool_s, 4),
            "ratio": round(ratio, 3),
            "n_lost": len(rep.lost),
            "lost_exec_seconds": round(rep.lost_exec_seconds, 2),
        }
        # gate only above timer noise (same threshold as bench_online)
        if repool_s >= 0.05 and ratio > max_ratio:
            failures.append(
                f"{pol} n={n}: fail() {fail_s:.3f}s > {max_ratio:g}x "
                f"cold repool {repool_s:.3f}s")
        print(f"recovery,{pol}_n{n}_fail_wall,{fail_s:.4f},s  "
              f"(repool {repool_s:.4f}s, ratio {ratio:.2f}, "
              f"lost {len(rep.lost)} tasks / "
              f"{rep.lost_exec_seconds:.0f} exec-s)")
    return results, failures


def bench_mtbf(mtbfs, policy, n, period, max_failures=25):
    from repro.core.cost_model import CostModel
    from repro.core.resources import paper_pool
    from repro.pipeline.workloads import ds_workload

    wl = ds_workload()
    cost = CostModel()
    results: dict = {}
    for mtbf in mtbfs:
        pool0 = paper_pool()
        drv = _mk_driver(wl, pool0, cost, policy, n, period)
        reports = []
        next_t = float(mtbf)
        rot = 0
        high = 0.0
        while True:
            a = drv.step()
            if a is None:
                if not drv.pending:
                    break
                continue
            if a.start > high:
                high = a.start
            if high >= next_t and len(reports) < max_failures:
                in_pool = {p.name for p in drv.pool.pes}
                victim = next((pe for pe in ROTATION[rot:] + ROTATION[:rot]
                               if pe in in_pool), None)
                if victim is not None:
                    rot = (ROTATION.index(victim) + 1) % len(ROTATION)
                    reports.append(drv.fail(next_t, [victim]))
                    # returning capacity: everything past its quarantine
                    # (never the victim — its window just opened)
                    drv.rejoin(next_t, pool0)
                next_t += mtbf
        sched = drv.schedule()
        useful = sum(x.finish - x.start - x.comm_wait
                     for x in sched.assignments)
        lost = sum(r.lost_exec_seconds for r in reports)
        mean_lat = (sum(r.wall_seconds for r in reports) / len(reports)
                    if reports else 0.0)
        makespan = max((x.finish for x in sched.assignments), default=0.0)
        results[str(mtbf)] = {
            "policy": policy,
            "n": n,
            "n_failures": len(reports),
            "goodput": round(useful / (useful + lost), 4) if useful else 0.0,
            "lost_work_ratio": round(lost / (useful + lost), 4)
            if useful else 0.0,
            "mean_recovery_ms": round(mean_lat * 1e3, 2),
            "makespan": round(makespan, 2),
            "cancelled": len(drv.cancelled_instances),
        }
        print(f"recovery,mtbf{mtbf}_{policy}_n{n},"
              f"{results[str(mtbf)]['goodput']:.4f},goodput  "
              f"({len(reports)} failures, lost ratio "
              f"{results[str(mtbf)]['lost_work_ratio']:.4f}, "
              f"{results[str(mtbf)]['mean_recovery_ms']:.1f}ms/recovery)")
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: latency at n=100 (eft+vos), sweep at "
                         "n=16 over mtbf 20,60; no file write unless "
                         "--out given explicitly")
    ap.add_argument("--n", type=int, default=1000,
                    help="instances for the latency experiment")
    ap.add_argument("--policies", default="eft,vos")
    ap.add_argument("--period", type=float, default=5.0)
    ap.add_argument("--mtbfs", default="50,200,800",
                    help="sim-seconds between injected PE deaths")
    ap.add_argument("--sweep-n", type=int, default=60,
                    help="instances for the MTBF sweep")
    ap.add_argument("--sweep-policy", default="eft")
    ap.add_argument("--out", default=None,
                    help="merge results under a 'recovery' key of this "
                         "JSON (typically BENCH_sched.json)")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail if fail() wall time exceeds this multiple "
                         "of a cold repool re-plan at the same point")
    args = ap.parse_args(argv)
    n = 100 if args.smoke else args.n
    sweep_n = 16 if args.smoke else args.sweep_n
    mtbfs = [20.0, 60.0] if args.smoke else [
        float(s) for s in args.mtbfs.split(",")]
    policies = ["eft", "vos"] if args.smoke else args.policies.split(",")
    t0 = time.perf_counter()
    latency, failures = bench_latency(n, policies, args.period,
                                      args.max_ratio)
    sweep = bench_mtbf(mtbfs, args.sweep_policy, sweep_n, args.period)
    if args.out:
        payload = {}
        if os.path.exists(args.out):
            with open(args.out) as f:
                payload = json.load(f)
        payload["recovery"] = {
            "meta": {
                "workload": "ds_workload x n on paper_pool, streamed via "
                            "OnlineDriver with injected PE deaths",
                "latency": "fail() wall (lineage+invalidate+replay+"
                           "resubmit) vs cold repool re-plan at the same "
                           "mid-flight point",
                "sweep": "PE death every mtbf sim-seconds, rotating "
                         "victim, quarantine-gated rejoin",
                "period": args.period,
                "total_seconds": round(time.perf_counter() - t0, 1),
            },
            "latency": latency,
            "mtbf_sweep": sweep,
        }
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out}")
    if failures:
        for msg in failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
