"""Scheduler micro-benchmark: wall-time per policy vs instance count.

    PYTHONPATH=src python benchmarks/bench_sched.py [--quick] \
        [--sizes 100,300,1000] [--policies eft,etf,...] [--out BENCH_sched.json]

Times each policy on ``ds_workload()`` merged ×n on ``paper_pool()`` (the
paper's Fig. 6/7 setting) and writes ``BENCH_sched.json``:

    {"meta": {...}, "results": {"<policy>": {"<n>": {"seconds": ...,
     "makespan": ..., "mean_utilization": ...}}}}

The checked-in ``BENCH_sched.json`` is the perf trajectory for future PRs:
regressions show up as a seconds increase at fixed (policy, n). The seed
(pre-incremental) engine measured ~3.5 s for EFT at n=100 and ~30 s at
n=300 on the same harness.
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def bench(sizes, policies, repeat: int = 1) -> dict:
    from repro.core.cost_model import CostModel
    from repro.core.resources import paper_pool
    from repro.core.simulator import run_instances
    from repro.pipeline.workloads import ds_workload

    wl = ds_workload()
    pool = paper_pool()
    cost = CostModel()
    results: dict = {}
    for pol in policies:
        results[pol] = {}
        for n in sizes:
            best = None
            for _ in range(repeat):
                t0 = time.perf_counter()
                r = run_instances(wl, pool, cost, policy=pol, n_instances=n)
                dt = time.perf_counter() - t0
                if best is None or dt < best[0]:
                    best = (dt, r)
            dt, r = best
            results[pol][str(n)] = {
                "seconds": round(dt, 4),
                "makespan": r.makespan,
                "mean_utilization": r.mean_utilization,
            }
            print(f"sched,{pol}_n{n}_wall,{dt:.3f},s  (makespan "
                  f"{r.makespan:.1f}s)")
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sizes for CI smoke (n=20,100)")
    ap.add_argument("--sizes", default="100,300,1000")
    ap.add_argument("--policies", default=",".join(
        ("rr", "etf", "etf_hwang", "eft", "heft", "minmin", "vos")))
    ap.add_argument("--out", default="BENCH_sched.json")
    args = ap.parse_args(argv)
    sizes = [20, 100] if args.quick else [int(s) for s in args.sizes.split(",")]
    policies = args.policies.split(",")
    t0 = time.perf_counter()
    results = bench(sizes, policies)
    payload = {
        "meta": {
            "workload": "ds_workload x n on paper_pool",
            "engine": "incremental (lazy best-candidate heap)",
            "sizes": sizes,
            "total_seconds": round(time.perf_counter() - t0, 1),
        },
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out} ({payload['meta']['total_seconds']}s total)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
