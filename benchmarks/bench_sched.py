"""Scheduler micro-benchmark: wall-time per policy vs instance count.

    PYTHONPATH=src python benchmarks/bench_sched.py [--quick] \
        [--sizes 100,300,1000,3000] [--policies eft,etf,...] \
        [--out BENCH_sched.json] [--check-golden] \
        [--baseline BENCH_sched.json --max-regression 3.0]

Times each policy on ``ds_workload()`` merged ×n on ``paper_pool()`` (the
paper's Fig. 6/7 setting) and writes ``BENCH_sched.json``. The pseudo-policy
``vos_hetero`` runs the VoS policy under the deterministic heterogeneous
per-instance SLO mix of :func:`repro.core.vos.slo_mix` (step / linear /
exponential curves, deadlines spread around the sweep's makespan scale) and
is gated to stay within ``HETERO_MAX_RATIO`` of the flat-curve vos run —
the piecewise-affine scaled-offset fast path at work. Output shape:

    {"meta": {...}, "results": {"<policy>": {"<n>": {"seconds": ...,
     "makespan": ..., "mean_utilization": ...}}}}

The merged problem is built once per size and shared across policies, and
``seconds`` times the scheduling engine only (the merge is recorded
separately in ``meta.merge_seconds``). The checked-in ``BENCH_sched.json``
is the perf trajectory for future PRs: regressions show up as a seconds
increase at fixed (policy, n).

CI gate flags:

  * ``--check-golden`` — recompute the sha256 assignment digest for every
    (policy, n) that has an entry in ``tests/golden_sched.json`` and fail
    on any divergence (the bench then doubles as a cheap byte-exactness
    smoke without importing the test suite);
  * ``--baseline PATH --max-regression X`` — fail if any (policy, n)
    wall-time exceeds X× the recorded baseline.

History: the seed (pre-incremental) engine measured ~3.5 s for EFT at
n=100 and ~31 s at n=1000; PR 1's lazy-heap engine reached 0.24 s / 31 s;
the class-grouped offset-heap engine (PR 2) runs EFT n=1000 in ~1.4 s and
n=3000 in ~4.6 s.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), os.pardir, "tests",
                           "golden_sched.json")


def _digest(sched) -> str:
    """Shared byte-identity recipe — see
    repro.core.schedulers.assignment_digest."""
    from repro.core.schedulers import assignment_digest
    return assignment_digest(sched.assignments)


#: the vos_hetero pseudo-policy must stay within this factor of the
#: flat-curve vos run at the same n — the piecewise-affine offset form
#: keeps heterogeneous SLO mixes on the fast path, and this gate keeps it
#: that way
HETERO_MAX_RATIO = 2.0

#: with the runtime sanitizer OFF (the default), the wiring in
#: schedulers/online may not tax a run by more than this factor over a
#: bare ``schedule()`` call — the checks must stay strictly opt-in
SANITIZE_MAX_OFF_RATIO = 1.05
SANITIZE_N = 300


def bench_sanitize(n: int = SANITIZE_N, repeat: int = 3):
    """Measure the :mod:`repro.core.sanitize` cost at ``n`` (eft policy).

    Three configurations, each best-of-``repeat``:

      * ``plain`` — a bare ``schedule()`` call on the premerged problem
        (exactly what the main sweep times);
      * ``off``  — the same problem through ``run_instances`` with
        ``sanitize=False`` (batch) / the online driver with the sanitizer
        disabled;
      * ``on``   — ``sanitize=True``: full invariant checking (batch gets
        a whole-schedule pass, online checks every placement live).

    Gate: batch *off* must stay within :data:`SANITIZE_MAX_OFF_RATIO` of
    *plain* — having the sanitizer wired in may not tax default runs.
    The *on* ratios are recorded, not gated: they are the documented
    price of ``REPRO_SANITIZE=1``.
    """
    from repro.core.cost_model import CostModel
    from repro.core.resources import paper_pool
    from repro.core.schedulers import schedule
    from repro.core.simulator import merge_instances, run_instances
    from repro.pipeline.workloads import ds_workload

    # an inherited REPRO_SANITIZE=1 (e.g. a sanitized CI job) would turn
    # the "off" runs on via the env fallback and void the gate — the
    # explicit flags below are the only sanitize control for this bench
    saved_env = os.environ.pop("REPRO_SANITIZE", None)

    wl = ds_workload()
    pool = paper_pool()
    cost = CostModel()
    premerged = merge_instances(wl, n)
    merged, arrival = premerged[0], premerged[1]

    def best(fn):
        b = None
        for _ in range(repeat):
            t0 = time.perf_counter()
            res = fn()
            dt = time.perf_counter() - t0
            # run_instances wraps its own timer around the engine; prefer
            # it so RunResult assembly does not pollute the comparison
            dt = getattr(res, "wall_seconds", None) or dt
            if b is None or dt < b:
                b = dt
        return b

    try:
        plain = best(lambda: schedule(merged, pool, cost, policy="eft",
                                      arrival=arrival))
        timings = {}
        for mode, kw in (("batch", {"_premerged": premerged}),
                         ("online", {"online": True})):
            off = best(lambda kw=kw: run_instances(
                wl, pool, cost, policy="eft", n_instances=n,
                sanitize=False, **kw))
            on = best(lambda kw=kw: run_instances(
                wl, pool, cost, policy="eft", n_instances=n,
                sanitize=True, **kw))
            timings[mode] = {
                "off_seconds": round(off, 4),
                "on_seconds": round(on, 4),
                "on_ratio": round(on / off, 3) if off > 0 else None,
            }
            print(f"sched,sanitize_{mode}_n{n},off {off:.3f}s  "
                  f"on {on:.3f}s  (x{timings[mode]['on_ratio']})")
    finally:
        if saved_env is not None:
            os.environ["REPRO_SANITIZE"] = saved_env

    failures = []
    off_b = timings["batch"]["off_seconds"]
    if plain >= 0.05 and off_b > SANITIZE_MAX_OFF_RATIO * plain:
        failures.append(
            f"sanitize-off batch n={n}: {off_b:.3f}s > "
            f"{SANITIZE_MAX_OFF_RATIO:g}x bare schedule() {plain:.3f}s "
            f"(sanitizer wiring is taxing default runs)")
    section = {
        "meta": {
            "n": n,
            "policy": "eft",
            "repeat": repeat,
            "max_off_ratio": SANITIZE_MAX_OFF_RATIO,
            "gate": "batch off_seconds <= max_off_ratio x plain_seconds",
        },
        "plain_seconds": round(plain, 4),
        "batch": timings["batch"],
        "online": timings["online"],
    }
    return section, failures


def bench(sizes, policies, repeat: int = 1, check_golden: bool = False):
    from repro.core.cost_model import CostModel
    from repro.core.resources import paper_pool
    from repro.core.schedulers import schedule
    from repro.core.simulator import merge_instances
    from repro.core.vos import slo_mix
    from repro.pipeline.workloads import ds_workload

    golden = {}
    failures: list = []
    if check_golden:
        if os.path.exists(GOLDEN_PATH):
            with open(GOLDEN_PATH) as f:
                golden = json.load(f)
        else:
            # an absent golden file must fail the gate, not silently
            # verify nothing
            failures.append(f"--check-golden: {GOLDEN_PATH} not found")

    wl = ds_workload()
    pool = paper_pool()
    cost = CostModel()
    results: dict = {pol: {} for pol in policies}
    merge_seconds: dict = {}
    for n in sizes:
        t0 = time.perf_counter()
        merged, arrival, _ = merge_instances(wl, n)
        merge_seconds[str(n)] = round(time.perf_counter() - t0, 4)
        for pol in policies:
            # "vos_hetero" = the vos policy under the deterministic
            # heterogeneous per-instance SLO mix of repro.core.vos.slo_mix
            # (deadlines spread around the sweep's makespan scale)
            kw = {}
            real_pol = pol
            if pol == "vos_hetero":
                real_pol = "vos"
                kw["curves"] = slo_mix(n, horizon=6.0 * n)
            best = None
            for _ in range(repeat):
                t0 = time.perf_counter()
                s = schedule(merged, pool, cost, policy=real_pol,
                             arrival=arrival, **kw)
                dt = time.perf_counter() - t0
                if best is None or dt < best[0]:
                    best = (dt, s)
            dt, s = best
            results[pol][str(n)] = {
                "seconds": round(dt, 4),
                "makespan": s.makespan,
                "mean_utilization": s.mean_utilization,
            }
            note = ""
            gkey = f"{pol}_n{n}"
            if gkey in golden:
                if _digest(s) == golden[gkey]["digest"]:
                    note = "  [golden OK]"
                else:
                    note = "  [GOLDEN DIVERGED]"
                    failures.append(f"{pol} n={n}: schedule diverged from "
                                    f"tests/golden_sched.json ({gkey})")
            print(f"sched,{pol}_n{n}_wall,{dt:.3f},s  (makespan "
                  f"{s.makespan:.1f}s){note}")
        het = results.get("vos_hetero", {}).get(str(n))
        flat = results.get("vos", {}).get(str(n))
        if het and flat and flat["seconds"] >= 0.05 \
                and het["seconds"] > HETERO_MAX_RATIO * flat["seconds"]:
            failures.append(
                f"vos_hetero n={n}: {het['seconds']:.3f}s > "
                f"{HETERO_MAX_RATIO:g}x flat-curve vos "
                f"{flat['seconds']:.3f}s (decay region fell off the "
                f"offset fast path?)")
    return results, merge_seconds, failures


def check_baseline(results: dict, baseline_path: str,
                   max_regression: float) -> list:
    with open(baseline_path) as f:
        base = json.load(f)["results"]
    failures = []
    for pol, by_n in results.items():
        for n, rec in by_n.items():
            ref = base.get(pol, {}).get(n, {}).get("seconds")
            # baselines are recorded on whatever machine last regenerated
            # BENCH_sched.json; below ~50 ms the 3x margin is mostly
            # scheduler/timer noise on a loaded CI runner — skip those
            if ref is None or ref < 0.05:
                continue
            if rec["seconds"] > max_regression * ref:
                failures.append(
                    f"{pol} n={n}: {rec['seconds']:.3f}s > "
                    f"{max_regression:g}x baseline {ref:.3f}s")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sizes for CI smoke (n=20,100)")
    ap.add_argument("--sizes", default="100,300,1000,3000")
    ap.add_argument("--policies", default=",".join(
        ("rr", "etf", "etf_hwang", "eft", "heft", "minmin", "vos",
         "vos_hetero")))
    ap.add_argument("--out", default="BENCH_sched.json")
    ap.add_argument("--check-golden", action="store_true",
                    help="fail if any schedule diverges from the golden "
                         "digests in tests/golden_sched.json")
    ap.add_argument("--check-sanitize", action="store_true",
                    help="time the runtime sanitizer off/on at n=300 (eft, "
                         "batch + online), gate the off overhead at "
                         f"{SANITIZE_MAX_OFF_RATIO:g}x, and record a "
                         "'sanitize' section")
    ap.add_argument("--baseline", default=None,
                    help="existing BENCH_sched.json to gate wall-time "
                         "regressions against")
    ap.add_argument("--max-regression", type=float, default=3.0,
                    help="fail if seconds exceed this multiple of the "
                         "baseline (with --baseline)")
    args = ap.parse_args(argv)
    sizes = [20, 100] if args.quick else [int(s) for s in args.sizes.split(",")]
    policies = args.policies.split(",")
    t0 = time.perf_counter()
    results, merge_seconds, failures = bench(
        sizes, policies, check_golden=args.check_golden)
    if args.baseline:
        failures += check_baseline(results, args.baseline,
                                   args.max_regression)
    sanitize_section = None
    if args.check_sanitize:
        sanitize_section, san_failures = bench_sanitize()
        failures += san_failures
    # BENCH_sched.json is a composite file (bench_online / bench_recovery /
    # bench_federation merge their own sections in) — update our keys,
    # never clobber the rest
    payload = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            payload = json.load(f)
    payload["meta"] = {
        "workload": "ds_workload x n on paper_pool",
        "engine": "incremental (candidate classes + offset sub-heaps)",
        "timing": "schedule() only; merge recorded in merge_seconds",
        "sizes": sizes,
        "merge_seconds": merge_seconds,
        "total_seconds": round(time.perf_counter() - t0, 1),
    }
    payload["results"] = results
    if sanitize_section is not None:
        payload["sanitize"] = sanitize_section
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out} ({payload['meta']['total_seconds']}s total)")
    if failures:
        for msg in failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
