"""Closed-loop serving-gateway benchmark: SLO tiers on the online driver.

    PYTHONPATH=src python benchmarks/bench_gateway.py \
        [--smoke] [--n 1000000] [--seed 0] [--out BENCH_sched.json] \
        [--capture-golden] [--max-event-us 0]

Replays a heavy-tailed bursty + diurnal arrival trace
(``repro.serve.gateway.synth_requests``: Zipf(2) burst sizes ×
Pareto(1.5) gaps, sinusoidal diurnal rate) through the
``ServingGateway`` — per-request tier curves, floor-ordered admission,
value-aware shedding, interactive-over-best-effort preemption — and
reports goodput, shed rate, preemption count, per-tier SLO attainment
and per-event runtime cost.

Tiers:

  * ``--smoke`` (CI): a small overloaded trace where shedding *and*
    preemption both fire; checks the schedule digest + serving metrics
    against tests/golden_gateway.json, absolute per-tier SLO-attainment
    floors, and the restart-from-durable-record differential (snapshot at
    a window boundary, restore, finish the trace — must be
    byte-identical). Runs sanitize-on in CI. Exit 1 on any divergence.
  * ``--n N``: the scale tier at the millions-of-requests/day operating
    point (24 slots provisioned for the *mean* arrival rate, so the
    diurnal peak plus bursts push it into overload and the gateway has
    real shedding/preemption work to do).

With ``--out`` the results are merged into BENCH_sched.json under a
``"gateway"`` key (other sections stay untouched).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

GOLDEN = os.path.join(os.path.dirname(__file__), "..", "tests",
                      "golden_gateway.json")

#: absolute per-tier SLO-attainment floors for the smoke trace — a
#: semantic gate on top of the byte-identity one: even under overload the
#: gateway must keep interactive attainment high by shedding/preempting
#: the cheap tiers first
SMOKE_ATTAINMENT_FLOORS = {"interactive": 0.90, "batch": 0.75}


def smoke_setup():
    from repro.serve.engine import EngineConfig
    from repro.serve.gateway import GatewayConfig
    ecfg = EngineConfig(max_batch=4, prefill_cost_per_tok=2e-4,
                        decode_cost_per_tok=0.02)
    gcfg = GatewayConfig(ecfg=ecfg, window_s=2.0, shed_backlog_s=3.0,
                         preempt_backlog_s=2.0,
                         max_preempt_probes_per_window=4)
    return gcfg, dict(n=1200, seed=0, mean_gap=1.2)


def scale_setup():
    from repro.serve.engine import EngineConfig
    from repro.serve.gateway import GatewayConfig
    ecfg = EngineConfig(max_batch=24, prefill_cost_per_tok=2e-4,
                        decode_cost_per_tok=0.02)
    # shedding only runs at window closes, so the shed control loop
    # needs tight windows AND a shed horizon under the interactive hard
    # deadline (8 s at slo_unit=2) — otherwise the diurnal peak parks
    # the backlog above every interactive budget and attainment
    # inverts. Preemption cost is decoupled from the window cadence by
    # the sim-time probe interval (each probe is O(history), see
    # GatewayConfig), and slo_quantum shares one shifted tier curve per
    # half-second of arrivals to keep candidate classes few at 10⁶ rids
    gcfg = GatewayConfig(ecfg=ecfg, window_s=5.0, shed_backlog_s=3.0,
                         preempt_backlog_s=8.0,
                         preempt_min_interval_s=600.0, slo_quantum=0.5)
    return gcfg, dict(mean_gap=0.175)


def run_gateway(gcfg, n, seed, mean_gap, sanitize=None):
    """Build the trace (not charged to the runtime), run the gateway,
    return (report, gateway, specs)."""
    from repro.serve.gateway import ServingGateway, synth_requests
    specs = synth_requests(n, seed=seed, mean_gap=mean_gap)
    gw = ServingGateway(gcfg, sanitize=sanitize)
    rep = gw.run(specs)
    return rep, gw, specs


def report_row(rep) -> dict:
    row = {
        "n_requests": rep.n_requests,
        "n_completed": rep.n_completed,
        "n_shed": rep.n_shed,
        "n_preemptions": rep.n_preemptions,
        "n_displaced": rep.n_displaced,
        "goodput": round(rep.goodput, 4),
        "shed_rate": round(rep.shed_rate, 4),
        "makespan_s": round(rep.makespan, 1),
        "attainment": {t: round(r["attainment"], 4)
                       for t, r in sorted(rep.per_tier.items())},
        "wall_seconds": round(rep.wall_seconds, 3),
        "per_event_us": round(1e6 * rep.wall_seconds
                              / max(rep.n_events, 1), 2),
    }
    return row


def restart_differential(gcfg, specs, sanitize=None):
    """Snapshot at a mid-trace window boundary, restore, finish — the
    continuation must be byte-identical to the uninterrupted run.
    Returns a list of failure strings (empty = pass)."""
    from repro.serve.gateway import ServingGateway
    full = ServingGateway(gcfg, sanitize=sanitize)
    rep_full = full.run(specs)
    w = [int(s.arrival // gcfg.window_s) for s in specs]
    bounds = [i for i in range(1, len(specs)) if w[i] > w[i - 1]]
    if not bounds:
        return ["restart differential needs >1 arrival window "
                "(trace too short for window_s)"]
    k = bounds[len(bounds) // 2]
    gw1 = ServingGateway(gcfg, sanitize=sanitize)
    for s in specs[:k]:
        gw1.offer(s)
    snap = gw1.snapshot()
    gw2 = ServingGateway.restore(snap, gcfg=gcfg, sanitize=sanitize)
    for s in specs[k:]:
        gw2.offer(s)
    gw2.drain()
    rep_split = gw2.report()
    failures = []
    if rep_split.digest != rep_full.digest:
        failures.append(f"restart differential: schedule diverged after "
                        f"restore at request {k}")
    a = dataclasses.asdict(rep_full)
    b = dataclasses.asdict(rep_split)
    for key in ("wall_seconds", "n_events"):  # telemetry, not the record
        a.pop(key)
        b.pop(key)
    if a != b:
        diff = sorted(key for key in a if a[key] != b[key])
        failures.append(f"restart differential: report fields diverged "
                        f"after restore: {diff}")
    return failures


def smoke(capture: bool, sanitize=None):
    gcfg, tr = smoke_setup()
    rep, _gw, specs = run_gateway(gcfg, sanitize=sanitize, **tr)
    row = report_row(rep)
    print(f"gateway-smoke,wall,{rep.wall_seconds:.3f},s  "
          f"(completed {rep.n_completed}/{rep.n_requests}, "
          f"shed {rep.n_shed}, preempt {rep.n_preemptions}, "
          f"goodput {rep.goodput:.3f})")
    failures = []
    if rep.n_shed == 0:
        failures.append("smoke trace no longer triggers load shedding")
    if rep.n_preemptions == 0:
        failures.append("smoke trace no longer triggers preemption")
    for tier, floor in sorted(SMOKE_ATTAINMENT_FLOORS.items()):
        att = row["attainment"][tier]
        if att < floor:
            failures.append(f"{tier} SLO attainment {att:.3f} < "
                            f"floor {floor}")
    golden = {
        "digest": rep.digest,
        "n_completed": rep.n_completed,
        "n_shed": rep.n_shed,
        "n_preemptions": rep.n_preemptions,
        "attainment": row["attainment"],
    }
    if capture:
        with open(GOLDEN, "w") as f:
            json.dump({"smoke": golden}, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"captured {os.path.normpath(GOLDEN)}")
    elif os.path.exists(GOLDEN):
        with open(GOLDEN) as f:
            want = json.load(f)["smoke"]
        if want != golden:
            diff = sorted(key for key in want if want.get(key) != golden.get(key))
            failures.append(f"golden mismatch vs tests/golden_gateway.json "
                            f"in {diff} (re-capture with --capture-golden "
                            f"only for intended schedule changes)")
    else:
        failures.append("tests/golden_gateway.json missing "
                        "(run --capture-golden)")
    failures.extend(restart_differential(gcfg, specs, sanitize=sanitize))
    return row, failures


def scale(n: int, seed: int, max_event_us: float):
    gcfg, tr = scale_setup()
    t0 = time.perf_counter()
    rep, _gw, specs = run_gateway(gcfg, n=n, seed=seed, **tr)
    trace_span = specs[-1].arrival - specs[0].arrival
    row = report_row(rep)
    row["trace_seed"] = seed
    row["trace_span_s"] = round(trace_span, 1)
    row["req_per_day"] = round(n * 86400.0 / max(trace_span, 1e-9))
    row["n_slots"] = gcfg.ecfg.max_batch
    row["total_seconds"] = round(time.perf_counter() - t0, 1)
    print(f"gateway-scale,n{n}_wall,{rep.wall_seconds:.1f},s  "
          f"({row['per_event_us']:.0f}us/event, "
          f"{row['req_per_day']:.2e} req/day simulated, "
          f"shed {rep.shed_rate:.1%}, preempt {rep.n_preemptions}, "
          f"goodput {rep.goodput:.3f})")
    for tier, att in row["attainment"].items():
        print(f"gateway-scale,{tier}_attainment,{att:.4f},ratio")
    failures = []
    if max_event_us and row["per_event_us"] > max_event_us:
        failures.append(f"scale n={n}: {row['per_event_us']:.1f}us/event > "
                        f"bound {max_event_us:g}us")
    return row, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: golden digest + attainment floors + "
                         "restart differential on a small overloaded trace")
    ap.add_argument("--capture-golden", action="store_true",
                    help="rewrite tests/golden_gateway.json from this run")
    ap.add_argument("--n", type=int, default=0,
                    help="scale tier: replay this many requests at the "
                         "millions/day operating point (0 = skip)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-event-us", type=float, default=0.0,
                    help="fail the scale tier above this per-event cost "
                         "(0 = report only)")
    ap.add_argument("--out", default=None,
                    help="merge results under a 'gateway' key of this JSON "
                         "(typically BENCH_sched.json)")
    args = ap.parse_args(argv)
    failures: list = []
    smoke_row = scale_row = None
    if args.smoke or args.capture_golden:
        smoke_row, sfail = smoke(args.capture_golden)
        failures.extend(sfail)
    if args.n:
        scale_row, sfail = scale(args.n, args.seed, args.max_event_us)
        failures.extend(sfail)
    if args.out:
        payload = {}
        if os.path.exists(args.out):
            with open(args.out) as f:
                payload = json.load(f)
        meta = {
            "trace": "synth_requests: Zipf(2) bursts x Pareto(1.5) gaps, "
                     "diurnal sinusoid (depth 0.7), tiers "
                     "interactive/batch/best_effort ~ 25/45/30, "
                     "bucketed prompt/decode lengths",
            "pipeline": "request -> prefill#rid -> decode#rid instance, "
                        "token-cost bridge onto one PE per decode slot",
            "policy": "vos floors; shed_pending on booked-backlog "
                      "overload; admit_preempting for interactive "
                      "arrivals into deep backlog",
        }
        section = dict(payload.get("gateway", ()))
        section["meta"] = meta
        if smoke_row is not None:
            section["smoke"] = smoke_row
        if scale_row is not None:
            section["scale"] = scale_row
        payload["gateway"] = section
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out}")
    if failures:
        for msg in failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
