"""(Re)capture scheduler golden values into tests/golden_sched.json.

    PYTHONPATH=src python benchmarks/capture_golden.py

Writes exact makespan / mean-utilization / total-energy floats and a
sha256 over the full assignment list for every policy at n=10 and n=100,
plus an arrival-period run. The checked-in goldens were captured from the
SEED (pre-incremental) engine and the incremental engine is pinned
byte-identical to them — regenerate only when scheduling *semantics* are
intentionally changed, and say so in the commit.
"""
import json
import sys
import time

from repro.core.cost_model import CostModel
from repro.core.resources import paper_pool
from repro.core.schedulers import POLICIES, assignment_digest
from repro.core.simulator import run_instances
from repro.pipeline.workloads import ds_workload


def sched_digest(sched):
    return assignment_digest(sched.assignments)


def main():
    out = {}
    wl = ds_workload()
    pool = paper_pool()
    cost = CostModel()
    for n in (10, 100):
        for pol in POLICIES:
            t0 = time.perf_counter()
            r = run_instances(wl, pool, cost, policy=pol, n_instances=n)
            dt = time.perf_counter() - t0
            out[f"{pol}_n{n}"] = {
                "makespan": r.makespan,
                "mean_utilization": r.mean_utilization,
                "total_energy": r.total_energy,
                "digest": sched_digest(r.schedule),
                "seed_seconds": round(dt, 3),
            }
            print(f"{pol:10s} n={n:<4d} {dt:8.3f}s mk={r.makespan:.6f}")
    # arrival-period regression (period > 0 exercises the arrival map)
    r = run_instances(wl, pool, cost, policy="eft", n_instances=10, period=7.5)
    out["eft_n10_period7.5"] = {
        "makespan": r.makespan,
        "mean_utilization": r.mean_utilization,
        "total_energy": r.total_energy,
        "digest": sched_digest(r.schedule),
    }
    # heterogeneous per-instance SLO curves (PR 5): captured from the
    # REFERENCE engine, so the pin is independent of the fast engine's
    # scaled-offset machinery (which is exactly what it protects)
    from repro.core.dag import merge
    from repro.core.schedulers_reference import schedule_reference
    from repro.core.vos import slo_mix
    n = 24
    merged = merge([wl.instance(i) for i in range(n)], name=f"x{n}")
    ref = schedule_reference(merged, pool, cost, policy="vos",
                             curves=slo_mix(n, horizon=6.0 * n))
    out[f"vos_hetero_n{n}"] = {
        "makespan": ref.makespan,
        "mean_utilization": ref.mean_utilization,
        "total_energy": ref.total_energy,
        "digest": sched_digest(ref),
        "captured_from": "reference engine "
                         "(schedulers_reference.schedule_vos)",
    }
    with open("tests/golden_sched.json", "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    print("wrote tests/golden_sched.json")


if __name__ == "__main__":
    sys.exit(main())
