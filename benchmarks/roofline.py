"""§Roofline table builder — reads results/dryrun/*.json (deliverable g).

For each (arch × shape × mesh) cell: the three roofline terms in seconds,
the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs ("useful compute" — catches
remat/redundancy waste), bytes/device, and a one-line mitigation note.

    PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
    PYTHONPATH=src python -m benchmarks.roofline --markdown
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def mitigation_note(d: Dict) -> str:
    r = d["roofline"]
    dom = r["dominant"]
    if dom == "collective_s":
        colls = d["hlo"]["collectives"]
        worst = max(colls, key=lambda k: colls[k]["ici_bytes"]
                    + colls[k]["dcn_bytes"]) if colls else "?"
        if d["hlo"]["collectives"].get("all-gather", {}).get("count", 0) > 500:
            return (f"per-chunk {worst} resharding storm — align attention/"
                    f"cache shardings so the kv scan stays local")
        return (f"{worst}-bound — overlap with compute / hierarchical "
                f"schedule / shard the other operand")
    if dom == "memory_s":
        if d["useful_flops_ratio"] < 0.3:
            return "low useful-FLOPs ratio — remove redundant/replicated compute first"
        return "memory-bound — fuse, increase arithmetic intensity (bigger microbatch per device)"
    return "compute-bound — good; push MXU utilisation (layout/fusion)"


def load(dir_: str) -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        rows.append(json.load(open(f)))
    return rows


def table(rows: List[Dict], markdown: bool = False) -> str:
    rows = sorted(rows, key=lambda d: (d["arch"],
                                       SHAPE_ORDER.index(d["shape"]),
                                       d["mesh"]))
    out = []
    if markdown:
        out.append("| arch | shape | mesh | compute_s | memory_s | coll_s "
                   "(ici/dcn) | dominant | useful | GB/dev | fits | note |")
        out.append("|---|---|---|---|---|---|---|---|---|---|---|")
    else:
        out.append(f"{'arch':<22}{'shape':<13}{'mesh':<7}{'compute':>10}"
                   f"{'memory':>10}{'coll':>10}{'dom':>6}{'useful':>8}"
                   f"{'GB/dev':>8}{'fits':>6}")
    for d in rows:
        if d.get("skipped"):
            if markdown:
                out.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | — "
                           f"| — | — | SKIP | — | — | — | {d['reason'][:60]} |")
            else:
                out.append(f"{d['arch']:<22}{d['shape']:<13}{d['mesh']:<7}"
                           f"{'SKIPPED (' + d['reason'][:48] + ')':>60}")
            continue
        r = d["roofline"]
        gb = d["memory_per_device"]["total_bytes"] / 1e9
        useful = min(d["useful_flops_ratio"], 9.99)
        if markdown:
            out.append(
                f"| {d['arch']} | {d['shape']} | {d['mesh']} "
                f"| {r['compute_s']*1e3:.1f} ms | {r['memory_s']*1e3:.1f} ms "
                f"| {r['collective_s']*1e3:.1f} ms "
                f"({r['ici_s']*1e3:.0f}/{r['dcn_s']*1e3:.0f}) "
                f"| {r['dominant'].replace('_s','')} | {useful:.2f} "
                f"| {gb:.1f} | {'y' if d['fits_hbm'] else 'N'} "
                f"| {mitigation_note(d)[:80]} |")
        else:
            out.append(
                f"{d['arch']:<22}{d['shape']:<13}{d['mesh']:<7}"
                f"{r['compute_s']*1e3:>9.1f}m{r['memory_s']*1e3:>9.1f}m"
                f"{r['collective_s']*1e3:>9.1f}m"
                f"{r['dominant'].replace('_s',''):>6}{useful:>8.2f}"
                f"{gb:>8.1f}{'y' if d['fits_hbm'] else 'N':>6}")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args(argv)
    rows = load(args.dir)
    if not rows:
        print(f"no dry-run results under {args.dir}; run "
              f"`python -m repro.launch.dryrun --all --mesh both` first")
        return 1
    print(table(rows, markdown=args.markdown))
    return 0


if __name__ == "__main__":
    sys.exit(main())
