"""Benchmark harness — one section per paper table/figure (deliverable d).

    PYTHONPATH=src python -m benchmarks.run [--quick]

Sections:
  fig6  — resource-pool configuration sweep (paper Fig. 6)
  fig7  — scheduling-policy sweep: exec time + mean utilisation (Fig. 7a/b)
  sched — scheduler engine wall-time per policy (see benchmarks/bench_sched.py)
  federation — edge↔DC scenario matrix: topology skew, WAN partition,
          site loss (see benchmarks/bench_federation.py)
  beyond — beyond-paper policies (HEFT / MinMin / VoS / Hwang-ETF)
  vos   — system-wide Value-of-Service per policy (paper §3/§4.2.3)
  exec  — real execution of the scheduled 16-task workload (host vs device)
  serve — request-scheduling policies on the serving engine
  kern  — kernel micro-benches (CPU interpret mode: correctness-path
          timings; TPU wall-times come from real hardware)
  roofline — summary of the dry-run roofline table (if results exist)

Output: CSV-ish `section,name,value,unit` lines + human tables.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np


def row(section: str, name: str, value, unit: str) -> None:
    print(f"{section},{name},{value},{unit}")


# ---------------------------------------------------------------------------
# Paper emulation benchmarks
# ---------------------------------------------------------------------------

def bench_fig6(n_instances: int) -> None:
    from repro.core.simulator import sweep_resource_configs, summarize
    from repro.pipeline.workloads import ds_workload
    res = sweep_resource_configs(ds_workload(), n_instances=n_instances)
    print(summarize(res))
    for r in res:
        row("fig6", r.label.replace(",", "+"), f"{r.makespan:.1f}", "s")
    best = min(res, key=lambda r: r.makespan)
    so = [r for r in res if r.label == "Server only"][0]
    row("fig6", "best_vs_server_only_reduction",
        f"{100 * (1 - best.makespan / so.makespan):.1f}", "%")


def bench_fig7(n_instances: int) -> None:
    from repro.core.simulator import sweep_policies, summarize
    from repro.pipeline.workloads import ds_workload
    res = sweep_policies(ds_workload(), n_instances=n_instances)
    print(summarize(res))
    d = {r.policy: r for r in res}
    for pol, r in d.items():
        row("fig7", f"{pol}_makespan", f"{r.makespan:.1f}", "s")
        row("fig7", f"{pol}_mean_util", f"{r.mean_utilization:.3f}", "frac")
    for pol in ("eft", "etf"):
        row("fig7", f"{pol}_vs_rr_time_reduction",
            f"{100 * (1 - d[pol].makespan / d['rr'].makespan):.1f}", "%")
        row("fig7", f"{pol}_vs_rr_util_gain",
            f"{100 * (d[pol].mean_utilization - d['rr'].mean_utilization):.1f}",
            "pts")


def bench_sched(quick: bool) -> None:
    """Engine wall-time per policy (the perf trajectory for the incremental
    scheduler); delegates to the micro-harness so numbers match
    BENCH_sched.json."""
    try:
        from benchmarks import bench_sched as bs
    except ImportError:
        # script mode (`python benchmarks/run.py`): sys.path[0] is
        # benchmarks/, not the repo root — load the sibling file directly
        import importlib.util
        path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "bench_sched.py")
        spec = importlib.util.spec_from_file_location("bench_sched", path)
        bs = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(bs)
    sizes = [20, 100] if quick else [100, 300]
    bs.bench(sizes, ("rr", "etf", "eft", "heft", "minmin"))


def _load_sibling(name: str):
    """Import a benchmarks/ sibling whether run as a module or a script."""
    try:
        import importlib
        return importlib.import_module(f"benchmarks.{name}")
    except ImportError:
        import importlib.util
        path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            f"{name}.py")
        spec = importlib.util.spec_from_file_location(name, path)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        return mod


def bench_federation(quick: bool) -> None:
    """Edge↔DC federation scenario matrix (WAN bytes, degraded-mode and
    site-loss trajectories); numbers match BENCH_sched.json's
    "federation" section."""
    bf = _load_sibling("bench_federation")
    bf.bench(12 if quick else 24, 4.0, "eft", check_golden=False)


def bench_beyond_policies(n_instances: int) -> None:
    from repro.core.simulator import sweep_policies
    from repro.pipeline.workloads import ds_workload
    res = sweep_policies(ds_workload(), n_instances=n_instances,
                         policies=("eft", "heft", "minmin", "vos",
                                   "etf_hwang"))
    for r in res:
        row("beyond", f"{r.policy}_makespan", f"{r.makespan:.1f}", "s")


def bench_vos(n_instances: int) -> None:
    from repro.core.simulator import sweep_policies
    from repro.core.vos import slo_mix, system_vos, uniform_specs
    from repro.pipeline.workloads import ds_workload
    res = sweep_policies(ds_workload(), n_instances=n_instances,
                         policies=("eft", "etf", "rr", "vos"))
    # value curve: full value if an instance finishes in the first third
    horizon = max(r.makespan for r in res)
    specs = uniform_specs(n_instances, soft=horizon / 3, hard=horizon,
                          energy_weight=1e-7)
    for r in res:
        v = system_vos(r.schedule, specs)
        row("vos", f"{r.policy}_system_vos", f"{v:.2f}",
            f"of {n_instances}")
    # per-instance SLO curves (PR 5): the VoS scheduler maximises against
    # each instance's own curve; score the same mix it optimised
    # (strict=True: the mix must cover every instance)
    curves = slo_mix(n_instances, horizon=horizon / 2)
    het = sweep_policies(ds_workload(), n_instances=n_instances,
                         policies=("eft", "vos"), curves=curves)
    for r in het:
        v = system_vos(r.schedule, curves, strict=True)
        row("vos", f"{r.policy}_hetero_system_vos", f"{v:.2f}",
            f"of {n_instances}")


def bench_execute() -> None:
    from repro.core.cost_model import CostModel
    from repro.core.executor import Executor
    from repro.core.resources import paper_pool
    from repro.core.schedulers import schedule
    from repro.pipeline.workloads import ds_workload_executable
    wl = ds_workload_executable()
    pool = paper_pool()
    sched = schedule(wl, pool, CostModel(), policy="eft")
    raw = np.random.default_rng(0).normal(0, 1, (2048, 8)).astype(np.float32)
    for backend in ("mixed", "host", "device"):
        of = (None if backend == "mixed"
              else (lambda pe, b=backend: b))
        ex = Executor(pool) if of is None else Executor(pool, backend_of=of)
        t0 = time.perf_counter()
        rep = ex.execute(wl, sched, inputs={"ingest": raw})
        row("exec", f"{backend}_16task_wall", f"{rep.wall_seconds*1e3:.1f}",
            "ms")


def bench_serve() -> None:
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    from repro.serve.engine import EngineConfig, Request, ServeEngine
    cfg = get_config("qwen3-0.6b", smoke=True)
    params = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [dict(rid=i,
                 prompt=rng.integers(2, cfg.vocab_size,
                                     size=int(rng.integers(4, 20))
                                     ).astype(np.int32),
                 max_new_tokens=int(rng.integers(4, 12)),
                 arrival=i * 0.3) for i in range(12)]
    for policy in ("fcfs", "eft", "edf"):
        eng = ServeEngine(cfg, params,
                          EngineConfig(max_batch=3, max_seq=96,
                                       policy=policy))
        for kw in reqs:
            eng.submit(Request(**kw))
        eng.run()
        st = eng.latency_stats()
        row("serve", f"{policy}_mean_latency", f"{st['mean_latency']:.1f}",
            "ticks")
        row("serve", f"{policy}_p95_latency", f"{st['p95_latency']:.1f}",
            "ticks")


def bench_kernels() -> None:
    import jax
    import jax.numpy as jnp
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.decode_attention import decode_attention
    from repro.kernels.kmeans import kmeans_assign
    from repro.kernels.window_agg import window_agg
    rng = np.random.default_rng(0)

    def timeit(fn, *args, n=3, **kw):
        fn(*args, **kw)  # compile/warm
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(fn(*args, **kw))
        return (time.perf_counter() - t0) / n * 1e6

    q = jnp.asarray(rng.normal(0, 1, (1, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 256, 2, 64)), jnp.float32)
    us = timeit(flash_attention, q, k, k, block_q=64, block_k=64)
    row("kern", "flash_attention_256x4x64", f"{us:.0f}", "us_interp")

    qd = jnp.asarray(rng.normal(0, 1, (4, 8, 64)), jnp.float32)
    kd = jnp.asarray(rng.normal(0, 1, (4, 512, 2, 64)), jnp.float32)
    us = timeit(decode_attention, qd, kd, kd)
    row("kern", "decode_attention_c512", f"{us:.0f}", "us_interp")

    x = jnp.asarray(rng.normal(0, 1, (2048, 16)), jnp.float32)
    c = jnp.asarray(rng.normal(0, 1, (16, 16)), jnp.float32)
    us = timeit(kmeans_assign, x, c)
    row("kern", "kmeans_assign_2048x16x16", f"{us:.0f}", "us_interp")

    w = jnp.asarray(rng.normal(0, 1, (1024, 8)), jnp.float32)
    us = timeit(window_agg, w, window=16, agg="mean")
    row("kern", "window_agg_1024x8_w16", f"{us:.0f}", "us_interp")


def bench_roofline() -> None:
    from benchmarks import roofline as rl
    rows = rl.load("results/dryrun")
    if not rows:
        row("roofline", "status", "no_dryrun_results", "-")
        return
    done = [d for d in rows if not d.get("skipped")]
    fits = sum(1 for d in done if d.get("fits_hbm"))
    row("roofline", "cells_compiled", len(done), "cells")
    row("roofline", "cells_skipped", len(rows) - len(done), "cells")
    row("roofline", "cells_fit_hbm", fits, "cells")
    for dom in ("compute_s", "memory_s", "collective_s"):
        n = sum(1 for d in done if d["roofline"]["dominant"] == dom)
        row("roofline", f"dominant_{dom.replace('_s','')}", n, "cells")
    print(rl.table(rows))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer instances for the emulation sweeps")
    ap.add_argument("--sections", default="all")
    args = ap.parse_args(argv)
    n = 20 if args.quick else 100
    sections = (("fig6", "fig7", "sched", "federation", "beyond", "vos",
                 "exec", "serve", "kern", "roofline")
                if args.sections == "all"
                else tuple(args.sections.split(",")))
    t0 = time.perf_counter()
    fns = {"fig6": lambda: bench_fig6(n), "fig7": lambda: bench_fig7(n),
           "sched": lambda: bench_sched(args.quick),
           "federation": lambda: bench_federation(args.quick),
           "beyond": lambda: bench_beyond_policies(n),
           "vos": lambda: bench_vos(n), "exec": bench_execute,
           "serve": bench_serve, "kern": bench_kernels,
           "roofline": bench_roofline}
    for s in sections:
        print(f"\n=== {s} ===")
        fns[s]()
    print(f"\ntotal {time.perf_counter() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
