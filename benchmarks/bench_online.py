"""Online-driver benchmark: per-event cost + digest parity vs the batch engine.

    PYTHONPATH=src python benchmarks/bench_online.py \
        [--sizes 100,1000] [--period 5.0] [--policies eft,etf] \
        [--out BENCH_sched.json] [--max-ratio 2.0] [--smoke]

For each (policy, n): schedule n instances of ``ds_workload()`` arriving
every ``period`` seconds on ``paper_pool()`` twice —

  * **batch**: merge all instances up front + one ``schedule()`` call (the
    offline path, timed like benchmarks/bench_sched.py);
  * **online**: stream them through ``repro.core.online.OnlineDriver``
    (instances admitted into the live engine as the admission gate pulls
    them in, retired when finished).

The two schedules are asserted byte-identical (sha256 over the assignment
list) — the bench doubles as the CI online-mode smoke (``--smoke``: tiny n,
nonzero period, exit 1 on divergence). Reported per (policy, n):

  * ``batch_seconds`` / ``online_seconds`` and their ratio — the online
    driver must stay within ``--max-ratio`` (default 2.0) of the batch
    engine at the same n (gated when the batch time is large enough to be
    meaningful);
  * ``per_event_us`` — online wall time per placement. This is the online
    claim: it tracks the *live* instance set (``max_live``), not the total
    instance count, so it stays flat as n grows at a fixed arrival rate.

With ``--out`` pointing at BENCH_sched.json the results are merged into
that file under an ``"online"`` key (the batch trajectory stays untouched).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


def bursty_arrivals(n: int, seed: int = 0, mean_gap: float = 5.0,
                    alpha: float = 1.5, max_burst: int = 64):
    """Heavy-tailed bursty arrival trace: ``n`` timestamps, grouped into
    Zipf-sized bursts of coincident arrivals separated by Pareto(``alpha``)
    quiet gaps (both heavy-tailed — the edge-traffic shape the scale tier
    exists for: long idle stretches punctuated by k-at-once floods that
    exercise the batched admission sweep). Deterministic per ``seed``."""
    import numpy as np
    rng = np.random.default_rng(seed)
    ts: list = []
    t = 0.0
    while len(ts) < n:
        burst = int(min(rng.zipf(2.0), max_burst))
        t += mean_gap * (rng.pareto(alpha) + 0.1)
        ts.extend([t] * burst)
    return ts[:n]


def bench_scale(n: int, policies, seed: int, max_event_us: float):
    """The n=10^4-class scale tier: stream ``n`` instances along a
    :func:`bursty_arrivals` trace through the online driver and gate the
    per-event cost. The trace (instance clones + timestamps) is built
    *before* the clock starts — workload synthesis is the generator's
    cost, not the runtime's — and byte-identity of the batched admission
    path is pinned separately by the serial-vs-batched differentials in
    tests/test_online.py, so this tier is pure runtime timing plus the
    batching/live-set telemetry."""
    from repro.core.cost_model import CostModel
    from repro.core.online import OnlineDriver
    from repro.core.resources import paper_pool
    from repro.pipeline.workloads import ds_workload

    wl = ds_workload()
    pool = paper_pool()
    cost = CostModel()
    arrivals = bursty_arrivals(n, seed=seed)
    trace = [(wl.instance(i), at) for i, at in enumerate(arrivals)]
    results: dict = {}
    failures: list = []
    for pol in policies:
        t0 = time.perf_counter()
        drv = OnlineDriver(pool, cost, policy=pol)
        for dag, at in trace:
            drv.submit(dag, arrival_t=at)
        drv.run()
        wall = time.perf_counter() - t0
        res = drv.result(wall_seconds=wall)
        per_event_us = wall / max(res.n_events, 1) * 1e6
        results[pol] = {
            "n": n,
            "trace_seed": seed,
            "wall_seconds": round(wall, 3),
            "per_event_us": round(per_event_us, 2),
            "n_events": res.n_events,
            "n_batched_steps": res.n_batched_steps,
            "max_live": res.max_live,
        }
        print(f"online-scale,{pol}_n{n}_wall,{wall:.3f},s  "
              f"({per_event_us:.1f}us/event, "
              f"{res.n_batched_steps} batched sweeps, "
              f"live<={res.max_live})")
        if max_event_us and per_event_us > max_event_us:
            failures.append(
                f"scale {pol} n={n}: {per_event_us:.1f}us/event > "
                f"bound {max_event_us:g}us")
    return results, failures


def bench(sizes, policies, period: float, max_ratio: float):
    from repro.core.cost_model import CostModel
    from repro.core.online import run_online
    from repro.core.resources import paper_pool
    from repro.core.schedulers import assignment_digest as _digest, schedule
    from repro.core.simulator import merge_instances
    from repro.core.vos import slo_mix
    from repro.pipeline.workloads import ds_workload

    wl = ds_workload()
    pool = paper_pool()
    cost = CostModel()
    results: dict = {pol: {} for pol in policies}
    failures: list = []
    for n in sizes:
        merged, arrival, _ = merge_instances(wl, n, period)
        for pol in policies:
            # "vos_hetero" = vos under the deterministic heterogeneous SLO
            # mix (same mix as benchmarks/bench_sched.py) — exercises the
            # per-instance floor admission gate at scale
            kw = {}
            real_pol = pol
            if pol == "vos_hetero":
                real_pol = "vos"
                kw["curves"] = slo_mix(n, horizon=6.0 * n)
            t0 = time.perf_counter()
            batch = schedule(merged, pool, cost, policy=real_pol,
                             arrival=arrival, **kw)
            batch_s = time.perf_counter() - t0
            online = run_online(wl, pool, cost, policy=real_pol,
                                n_instances=n, period=period, **kw)
            online_s = online.wall_seconds
            if _digest(batch.assignments) != _digest(
                    online.schedule.assignments):
                failures.append(f"{pol} n={n}: online schedule diverged "
                                f"from the batch engine")
            ratio = online_s / batch_s if batch_s > 0 else float("inf")
            per_event_us = online_s / max(online.n_events, 1) * 1e6
            results[pol][str(n)] = {
                "batch_seconds": round(batch_s, 4),
                "online_seconds": round(online_s, 4),
                "ratio": round(ratio, 3),
                "per_event_us": round(per_event_us, 2),
                "max_live": online.max_live,
                "period": period,
            }
            # gate only when the batch time is above timer noise (same
            # threshold as bench_sched's baseline gate)
            if batch_s >= 0.05 and ratio > max_ratio:
                failures.append(
                    f"{pol} n={n}: online {online_s:.3f}s > "
                    f"{max_ratio:g}x batch {batch_s:.3f}s")
            print(f"online,{pol}_n{n}_wall,{online_s:.3f},s  "
                  f"(batch {batch_s:.3f}s, ratio {ratio:.2f}, "
                  f"{per_event_us:.0f}us/event, live<={online.max_live})")
    return results, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: n=24, nonzero period, "
                         "eft+etf+vos+vos_hetero, no file write unless "
                         "--out given explicitly")
    ap.add_argument("--sizes", default="100,1000")
    ap.add_argument("--period", type=float, default=5.0,
                    help="arrival period in seconds (0 = all at once)")
    ap.add_argument("--policies", default="eft,etf")
    ap.add_argument("--out", default=None,
                    help="merge results under an 'online' key of this JSON "
                         "(typically BENCH_sched.json)")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail if online wall time exceeds this multiple "
                         "of the batch engine at the same n")
    ap.add_argument("--scale", type=int, default=0,
                    help="also run the bursty-trace scale tier at this n "
                         "(0 = skip)")
    ap.add_argument("--scale-policies", default="etf,eft",
                    help="policies for the scale tier")
    ap.add_argument("--trace-seed", type=int, default=0,
                    help="seed for the bursty arrival trace")
    ap.add_argument("--max-event-us", type=float, default=0.0,
                    help="fail if the scale tier exceeds this per-event "
                         "cost (0 = report only)")
    args = ap.parse_args(argv)
    sizes = [24] if args.smoke else [int(s) for s in args.sizes.split(",")]
    policies = (["eft", "etf", "vos", "vos_hetero"] if args.smoke
                else args.policies.split(","))
    t0 = time.perf_counter()
    results, failures = bench(sizes, policies, args.period, args.max_ratio)
    scale_results = None
    if args.scale:
        scale_results, sfail = bench_scale(args.scale,
                                           args.scale_policies.split(","),
                                           args.trace_seed,
                                           args.max_event_us)
        failures.extend(sfail)
    if args.out:
        payload = {}
        if os.path.exists(args.out):
            with open(args.out) as f:
                payload = json.load(f)
        payload["online"] = {
            "meta": {
                "workload": "ds_workload x n on paper_pool, streamed via "
                            "repro.core.online.OnlineDriver",
                "timing": "driver submit+run wall vs schedule() on the "
                          "premerged problem",
                "period": args.period,
                "total_seconds": round(time.perf_counter() - t0, 1),
            },
            "results": results,
        }
        if scale_results is not None:
            payload["online"]["scale"] = {
                "meta": {
                    "trace": "bursty_arrivals: Zipf(2) burst sizes x "
                             "Pareto(1.5) gaps, pre-generated (synthesis "
                             "not charged to the runtime)",
                    "seed": args.trace_seed,
                },
                "results": scale_results,
            }
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out}")
    if failures:
        for msg in failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
