"""Calibration sweep for the emulation constants (DESIGN.md §2, EXPERIMENTS
§Paper-repro).

The paper publishes only aggregate results (−57 % vs RR, −57 % vs
server-only, +21 pts utilisation, extremes worst), not its per-(task, PE)
execution-time tables. This sweep grids the free constants — heavy-task
work scale, inter-task byte scale, ARM ML rate — and scores each cell by
distance to the paper's aggregates; repro.pipeline.workloads._NODES and
repro.core.cost_model.RATE hold the chosen point.

    PYTHONPATH=src python -m benchmarks.calibration [--instances 50]
"""

from __future__ import annotations

import argparse
import sys

from repro.core.cost_model import CostModel, RATE
from repro.core import dag as dag_mod
from repro.core.dag import PipelineDAG, Task
from repro.core.resources import paper_pool
from repro.core.schedulers import schedule
from repro.pipeline import workloads as W

MB = 1e6


def build(raw_mb: float, heavy_scale: float, byte_scale: float) -> PipelineDAG:
    g = PipelineDAG("ds")
    for op, work, out in W._NODES:
        w = work * (heavy_scale if work >= 10 else 1.0)
        g.add_task(Task(op, op, work=w,
                        out_bytes=(raw_mb * MB if op == "ingest"
                                   else out * byte_scale),
                        in_bytes=(raw_mb * MB if op == "ingest" else 0.0)))
    for a, b in W._EDGES:
        g.add_edge(a, b)
    return g


def run(wl, pool, policy, cost, n):
    merged = dag_mod.merge([wl.instance(i) for i in range(n)])
    return schedule(merged, pool, cost, policy=policy)


def score_cell(arm_ml, hs, bs, n):
    rate = {f: dict(r) for f, r in RATE.items()}
    rate["ml"]["arm"] = arm_ml
    rate["stream"]["arm"] = min(arm_ml, 2.0)
    cost = CostModel(rate=rate)
    wl = build(16, hs, bs)
    pool = paper_pool()
    eft = run(wl, pool, "eft", cost, n)
    etf = run(wl, pool, "etf", cost, n)
    rr = run(wl, pool, "rr", cost, n)
    so = run(wl, paper_pool(n_arm=0, n_volta=0), "eft", cost, n)
    eo = run(wl, paper_pool(n_xeon=0, n_v100=0, n_alveo=0), "eft", cost, n)
    t_rr = 100 * (1 - eft.makespan / rr.makespan)
    t_so = 100 * (1 - eft.makespan / so.makespan)
    du = 100 * (eft.mean_utilization - rr.mean_utilization)
    worst = (eo.makespan > max(eft.makespan, etf.makespan)
             and so.makespan > max(eft.makespan, etf.makespan))
    close = 100 * abs(eft.makespan - etf.makespan) / eft.makespan
    dist = (abs(t_rr - 57) + abs(t_so - 57) + abs(du - 21)
            + (0 if worst else 100) + close)
    return dist, dict(t_rr=t_rr, t_so=t_so, du=du, worst=worst, close=close)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--instances", type=int, default=50)
    args = ap.parse_args(argv)
    best = None
    for arm_ml in (1.0, 2.0, 4.0):
        for hs in (0.4, 0.6, 1.0):
            for bs in (0.5, 1.0):
                dist, info = score_cell(arm_ml, hs, bs, args.instances)
                print(f"arm_ml={arm_ml} heavy={hs} bytes={bs}: "
                      f"dist={dist:6.1f} {info}")
                if best is None or dist < best[0]:
                    best = (dist, arm_ml, hs, bs)
    print(f"\nbest: dist={best[0]:.1f} arm_ml={best[1]} heavy={best[2]} "
          f"bytes={best[3]} (chosen point lives in workloads._NODES/RATE)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
