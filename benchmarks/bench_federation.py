"""Federation benchmark: the edge↔DC scenario matrix (robustness PR).

    PYTHONPATH=src python benchmarks/bench_federation.py \
        [--n 24] [--policy eft] [--period 4.0] \
        [--out BENCH_sched.json] [--smoke] [--max-seconds 120]

Four deterministic scenarios of ``ds_workload`` instances streaming onto
a two-site :func:`~repro.core.federation.paper_federation` (data gravity
on: ``CostModel(data_home=...)`` prices raw-input uploads over the WAN):

  * **edge_heavy** — the edge box outnumbers the DC (6×ARM + 2×Volta vs
    1×Xeon): data gravity plus capacity keeps the pipeline at home, so
    WAN bytes stay near the residual cross-site pulls.
  * **dc_heavy** — the DC dwarfs the edge (1×ARM vs 6×Xeon + 2×V100 +
    2×Alveo): compute pulls stages backend-ward and pays the 4G uplink.
  * **partitioned_wan** — the paper topology; mid-flight the WAN cuts
    the DC off (``partition(..., defer="all")``), the driver keeps
    placing edge-side work (degraded mode), and the cut heals. Nothing
    is recomputed — a partition is pricing, not surgery.
  * **site_loss** — the DC dies outright (``fail_site``): in-flight and
    orphaned work recomputes on the edge, and the site rejoins after its
    quarantine window.

Per scenario: makespan, goodput (useful exec-seconds over useful +
invalidated), recomputed work, WAN bytes/crossings
(:func:`~repro.core.federation.wan_traffic`), and the schedule's sha256
assignment digest.

``--smoke`` (CI gate): small n; every digest must match
``tests/golden_federation.json`` (absent file fails the gate) and the
whole matrix must finish within ``--max-seconds`` wall time.
``--out`` merges results under a ``"federation"`` key of the given JSON
(typically BENCH_sched.json; other sections stay untouched).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           os.pardir, "tests", "golden_federation.json")

SCENARIOS = ("edge_heavy", "dc_heavy", "partitioned_wan", "site_loss")


def _federation(scenario):
    from repro.core.federation import paper_federation
    if scenario == "edge_heavy":
        return paper_federation(n_arm=6, n_volta=2, n_xeon=1, n_v100=0,
                                n_alveo=0)
    if scenario == "dc_heavy":
        return paper_federation(n_arm=1, n_volta=0, n_xeon=6, n_v100=2,
                                n_alveo=2)
    return paper_federation()  # the paper topology, for the fault scripts


def _high(drv) -> float:
    return max((a.start for a in drv.eng.assignments), default=0.0)


def run_scenario(scenario: str, n: int, period: float, policy: str) -> dict:
    from repro.core.cost_model import CostModel
    from repro.core.federation import wan_traffic
    from repro.core.online import OnlineDriver
    from repro.core.schedulers import assignment_digest
    from repro.pipeline.workloads import ds_workload

    wl = ds_workload()
    fed = _federation(scenario)
    cost = CostModel(data_home=fed.data_home)
    drv = OnlineDriver(fed, cost, policy=policy)
    for i in range(n):
        drv.submit(wl.instance(i), arrival_t=i * period)

    recomputed = 0.0
    events: list = []
    if scenario in ("partitioned_wan", "site_loss"):
        # place ~25% of the stream, fire the event at the placement
        # horizon, run degraded for a few steps, then recover — all
        # sim-time choices derived from the record, so the scenario is
        # deterministic and its digest pinnable
        for _ in range(max(len(wl.tasks) * n // 4, 8)):
            drv.step()
        t0 = _high(drv)
        if scenario == "partitioned_wan":
            drv.partition(t0, "dc", defer="all")
            for _ in range(8):
                drv.step()
            th = max(t0 + 15.0, _high(drv))  # inside the 30 s window
            rep = drv.heal(th, "dc")
            events.append("partition@%.1f heal@%.1f%s" % (
                t0, th, "" if rep is None else " (late->escalated)"))
            if rep is not None:
                recomputed += rep.lost_exec_seconds
        else:
            rep = drv.fail_site(t0, "dc")
            recomputed += rep.lost_exec_seconds
            for _ in range(8):
                drv.step()
            tr = max(t0 + 31.0, _high(drv))  # past the quarantine window
            accepted, _refused = drv.rejoin_site(tr, "dc")
            while not accepted:  # flap-damped: try past the next window
                tr += 30.0
                accepted, _refused = drv.rejoin_site(tr, "dc")
            events.append("fail_site@%.1f rejoin@%.1f" % (t0, tr))

    sched = drv.run()
    useful = sum(a.finish - a.start - a.comm_wait for a in sched.assignments)
    traffic = wan_traffic(sched.assignments,
                          [inst.dag for inst in drv.instances],
                          drv.pool, data_home=fed.data_home)
    return {
        "policy": policy,
        "n": n,
        "makespan": round(max((a.finish for a in sched.assignments),
                              default=0.0), 3),
        "goodput": round(useful / (useful + recomputed), 4)
        if useful else 0.0,
        "recomputed_exec_seconds": round(recomputed, 2),
        "wan_bytes": round(traffic.bytes_moved, 0),
        "wan_upload_bytes": round(traffic.upload_bytes, 0),
        "wan_crossings": traffic.crossings,
        "events": events,
        "digest": assignment_digest(sched.assignments),
    }


def bench(n: int, period: float, policy: str, check_golden: bool):
    results: dict = {}
    failures: list = []
    golden = {}
    if check_golden:
        if os.path.exists(GOLDEN_PATH):
            with open(GOLDEN_PATH) as f:
                golden = json.load(f)
        else:
            # an absent golden file must fail the gate, not silently pass
            failures.append(f"--check-golden: {GOLDEN_PATH} not found")
    for scenario in SCENARIOS:
        r = run_scenario(scenario, n, period, policy)
        results[scenario] = r
        note = ""
        gkey = f"{scenario}_{policy}_n{n}"
        if gkey in golden:
            if r["digest"] == golden[gkey]["digest"]:
                note = "  [golden OK]"
            else:
                note = "  [golden DIVERGED]"
                failures.append(
                    f"{scenario}: digest diverged from "
                    f"tests/golden_federation.json ({gkey})")
        elif check_golden and not failures:
            failures.append(f"--check-golden: no golden entry {gkey}")
        print(f"federation,{scenario}_makespan,{r['makespan']:.1f},s  "
              f"(goodput {r['goodput']:.4f}, recomputed "
              f"{r['recomputed_exec_seconds']:.0f} exec-s, WAN "
              f"{r['wan_bytes'] / 1e6:.1f} MB / {r['wan_crossings']} "
              f"crossings){note}")
    return results, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: n=12, digests vs "
                         "tests/golden_federation.json, walltime bound")
    ap.add_argument("--n", type=int, default=24,
                    help="instances streamed per scenario")
    ap.add_argument("--period", type=float, default=4.0)
    ap.add_argument("--policy", default="eft")
    ap.add_argument("--check-golden", action="store_true",
                    help="fail on digest divergence from "
                         "tests/golden_federation.json")
    ap.add_argument("--write-golden", action="store_true",
                    help="(re)write tests/golden_federation.json from "
                         "this run")
    ap.add_argument("--max-seconds", type=float, default=120.0,
                    help="smoke walltime gate over the whole matrix")
    ap.add_argument("--out", default=None,
                    help="merge results under a 'federation' key of this "
                         "JSON (typically BENCH_sched.json)")
    args = ap.parse_args(argv)
    n = 12 if args.smoke else args.n
    check = args.check_golden or args.smoke
    t0 = time.perf_counter()
    results, failures = bench(n, args.period, args.policy,
                              check_golden=check and not args.write_golden)
    wall = time.perf_counter() - t0
    print(f"federation,matrix_wall,{wall:.2f},s")
    if args.smoke and wall > args.max_seconds:
        failures.append(
            f"matrix took {wall:.1f}s > --max-seconds {args.max_seconds:g}")
    if args.write_golden:
        payload = {
            f"{scenario}_{args.policy}_n{n}": {
                "digest": r["digest"],
                "makespan": r["makespan"],
                "wan_bytes": r["wan_bytes"],
            }
            for scenario, r in results.items()
        }
        with open(GOLDEN_PATH, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {GOLDEN_PATH}")
    if args.out:
        payload = {}
        if os.path.exists(args.out):
            with open(args.out) as f:
                payload = json.load(f)
        payload["federation"] = {
            "meta": {
                "workload": "ds_workload x n streamed onto "
                            "paper_federation variants via OnlineDriver, "
                            "data gravity on (CostModel data_home)",
                "scenarios": "edge_heavy / dc_heavy (topology skew), "
                             "partitioned_wan (cut+defer+heal), "
                             "site_loss (fail_site+quarantined rejoin)",
                "period": args.period,
                "total_seconds": round(wall, 1),
            },
            "scenarios": results,
        }
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out}")
    if failures:
        for msg in failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
